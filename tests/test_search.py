"""Search-correctness harness for the device-resident annealer.

Four layers of defense around :mod:`repro.core.search_jax`:

* **kernel parity** — the Metropolis/incumbent select step is bit-identical
  between the fused-XLA reference and the Pallas kernel body (interpret
  mode on CPU), including error-poisoned (non-finite) lanes;
* **seeded determinism** — the same ``(seed, population, steps, island,
  exchange_every)`` returns the bit-identical incumbent regardless of how
  the population is chunked across device calls, which selection-kernel
  backend ran, and (for well-separated optima) whether ranking used
  float32 or float64;
* **differential** — every device-search incumbent, re-simulated through
  the authoritative scalar simulator, matches its device-reported
  objective within dtype-scaled tolerances (the property runs over the
  same seeded scenario generator as the simulator differential suite);
* **optimality bounds** — on exhaustively enumerable problems the search
  finds the true optimum; on the golden Table-6 fixtures it is never
  worse than greedy and within 2% of the exact branch-and-bound plan.

The wide population sweep is ``@pytest.mark.slow`` (scheduled CI lane);
everything else is fast-lane smoke.
"""
from __future__ import annotations

import pathlib

import numpy as np
import pytest

from _prop import examples, given, search_problems, settings

from repro.core.accelerators import Accelerator, Platform
from repro.core.contention import ProportionalShareModel
from repro.core.graph import DNNGraph, LayerGroup
from repro.core.simulate import Workload, simulate
from repro.core.solver_bb import enumerate_assignments

try:
    from repro.core import search_jax
    HAVE_JAX = search_jax.HAVE_JAX
except ImportError:  # pragma: no cover
    HAVE_JAX = False

pytestmark = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")

FIXTURES = sorted(
    (pathlib.Path(__file__).parent / "fixtures" / "plans").glob("*.json"))


# ---------------------------------------------------------------------------
# problems
# ---------------------------------------------------------------------------

def _acc(name: str, tin: float, tout: float) -> Accelerator:
    return Accelerator(name, peak_flops=1e12, mem_bw=1e11,
                       transition_in_ms=tin, transition_out_ms=tout)


def tiny_problem():
    """Two 3-group DNNs on two accelerators: 64 joint candidates, small
    enough to brute-force with the scalar simulator."""
    platform = Platform(
        name="tiny", accelerators=(_acc("GPU", 0.02, 0.03),
                                   _acc("DLA", 0.05, 0.01)),
        transition_bw=1e11, domains={"EMC": ("GPU", "DLA")},
        domain_bw={"EMC": 1e11})

    def grp(i, tg, td, dg, dd):
        return LayerGroup(name=f"g{i}", times={"GPU": tg, "DLA": td},
                          mem_demand={"GPU": dg, "DLA": dd},
                          out_bytes=2e7, can_transition_after=True)

    graphs = [
        DNNGraph("a", (grp(0, 1.0, 1.6, 0.7, 0.4),
                       grp(1, 2.0, 1.1, 0.5, 0.6),
                       grp(2, 0.8, 1.9, 0.9, 0.3))),
        DNNGraph("b", (grp(0, 1.4, 0.9, 0.6, 0.5),
                       grp(1, 0.7, 1.5, 0.8, 0.2),
                       grp(2, 1.8, 1.0, 0.4, 0.7))),
    ]
    model = ProportionalShareModel(capacity=1.0, sensitivity=2.0)
    return platform, graphs, model


def xavier_pair():
    from repro.core import Scheduler
    sched = Scheduler("xavier-agx")
    return sched.platform, sched.graphs(["googlenet", "resnet18"]), \
        sched.model


def scalar_objective(platform, graphs, model, assignment, objective,
                     its, deps, arr=None):
    arr = arr or [0.0] * len(graphs)
    wls = [Workload(g, tuple(a), iterations=it, depends_on=dep,
                    arrival_ms=a0)
           for g, a, it, dep, a0 in zip(graphs, assignment, its, deps, arr)]
    return simulate(platform, wls, model,
                    record_timeline=False).objective(objective)


def brute_force(platform, graphs, model, objective, mt, its, deps):
    best = np.inf
    cand = [enumerate_assignments(g, platform.names, mt) for g in graphs]
    import itertools
    for asgs in itertools.product(*cand):
        best = min(best, scalar_objective(platform, graphs, model, asgs,
                                          objective, its, deps))
    return best


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------

class TestSelectKernelParity:
    def _inputs(self, p=64, l=6, dtype=np.float32, seed=0):
        rng = np.random.default_rng(seed)
        cur = rng.integers(0, 3, size=(p, l)).astype(np.int32)
        prop = rng.integers(0, 3, size=(p, l)).astype(np.int32)
        best = rng.integers(0, 3, size=(p, l)).astype(np.int32)
        curo = rng.uniform(1, 10, p).astype(dtype)
        propo = rng.uniform(1, 10, p).astype(dtype)
        besto = rng.uniform(1, 10, p).astype(dtype)
        propo[3] = np.inf            # error-poisoned lane
        u = rng.uniform(0, 1, p).astype(dtype)
        temp = np.asarray(0.37, dtype)
        return cur, prop, best, curo, propo, besto, u, temp

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_xla_matches_pallas_interpret_bitwise(self, dtype):
        from repro.kernels.search import anneal_select
        args = self._inputs(dtype=dtype)
        ref = anneal_select(*args, backend="xla")
        ker = anneal_select(*args, backend="pallas_interpret")
        for r, k in zip(ref, ker):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(k))

    def test_nonfinite_proposals_always_reject(self):
        from repro.kernels.search import anneal_select
        cur, prop, best, curo, propo, besto, u, temp = self._inputs()
        propo[:] = -np.inf           # "better than anything" but poisoned
        ncur, ncuro, nbst, nbsto = anneal_select(
            cur, prop, best, curo, propo, besto, u, temp, backend="xla")
        np.testing.assert_array_equal(np.asarray(ncur), cur)
        np.testing.assert_array_equal(np.asarray(ncuro), curo)

    def test_strict_improvements_fold_into_incumbent(self):
        from repro.kernels.search import anneal_select
        cur, prop, best, curo, propo, besto, u, temp = self._inputs()
        better = propo < besto
        _, _, nbst, nbsto = anneal_select(
            cur, prop, best, curo, propo, besto, u, temp, backend="xla")
        np.testing.assert_array_equal(np.asarray(nbsto),
                                      np.where(better, propo, besto))
        np.testing.assert_array_equal(np.asarray(nbst)[better], prop[better])
        np.testing.assert_array_equal(np.asarray(nbst)[~better],
                                      best[~better])

    def test_unknown_backend_raises(self):
        from repro.kernels.search import anneal_select
        with pytest.raises(ValueError, match="backend"):
            anneal_select(*self._inputs(), backend="cuda")


# ---------------------------------------------------------------------------
# seeded determinism
# ---------------------------------------------------------------------------

class TestDeterminism:
    KW = dict(objective="latency", seed=7, population=32, steps=24,
              island=8, exchange_every=4)

    @pytest.fixture(scope="class")
    def tables(self):
        platform, graphs, model = xavier_pair()
        return search_jax.build_tables(platform, graphs, model, 2)

    def test_same_seed_bit_identical(self, tables):
        a = search_jax.anneal_search(tables, **self.KW)
        b = search_jax.anneal_search(tables, **self.KW)
        assert a.assignment == b.assignment
        assert a.objective == b.objective
        assert a.chain == b.chain

    @pytest.mark.parametrize("chunk", [8, 16, 32])
    def test_chunk_invariance(self, tables, chunk):
        ref = search_jax.anneal_search(tables, chunk=32, **self.KW)
        out = search_jax.anneal_search(tables, chunk=chunk, **self.KW)
        assert out.assignment == ref.assignment
        assert out.objective == ref.objective
        assert out.chain == ref.chain

    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    def test_select_backend_invariance(self, tables, backend):
        ref = search_jax.anneal_search(tables, backend="xla", **self.KW)
        out = search_jax.anneal_search(tables, backend=backend, **self.KW)
        assert out.assignment == ref.assignment
        assert out.objective == ref.objective

    def test_precision_equivalent_quality(self, tables):
        kw = dict(self.KW, population=128, steps=64)
        f32 = search_jax.anneal_search(tables, precision="float32", **kw)
        f64 = search_jax.anneal_search(tables, precision="x64", **kw)
        # Metropolis deltas differ in the last ulp between precisions, so
        # trajectories may diverge to symmetric incumbents — but float32
        # ranking must not cost solution quality: both precisions land on
        # the same objective to single-precision accuracy, and each
        # incumbent survives a scalar re-simulation.
        assert f32.objective == pytest.approx(f64.objective, rel=1e-4)
        platform, graphs, model = xavier_pair()
        for out, rtol in ((f32, 1e-3), (f64, 1e-6)):
            host = scalar_objective(platform, graphs, model, out.assignment,
                                    "latency", [1, 1], [None, None])
            assert out.objective == pytest.approx(host, rel=rtol)

    def test_evaluated_counts_population_times_steps(self, tables):
        out = search_jax.anneal_search(tables, **self.KW)
        assert out.evaluated == out.population * (self.KW["steps"] + 1)
        assert out.population == 32


# ---------------------------------------------------------------------------
# differential: device incumbent vs authoritative scalar simulator
# ---------------------------------------------------------------------------

class TestDifferential:
    @given(prob=search_problems())
    @settings(max_examples=examples(6))
    def test_device_objective_matches_scalar_rerun(self, prob):
        platform, graphs, model, its, deps, arr = prob
        mt = max(len(g) for g in graphs)
        tables = search_jax.build_tables(
            platform, graphs, model, mt, iterations=its, depends_on=deps,
            arrival_ms=arr)
        for precision, rtol in (("x64", 1e-6), ("float32", 1e-3)):
            out = search_jax.anneal_search(
                tables, objective="latency", seed=3, population=16,
                steps=12, island=8, precision=precision)
            host = scalar_objective(platform, graphs, model, out.assignment,
                                    "latency", its, deps, arr)
            assert out.objective == pytest.approx(host, rel=rtol,
                                                  abs=rtol), precision


# ---------------------------------------------------------------------------
# optimality bounds
# ---------------------------------------------------------------------------

class TestOptimality:
    @pytest.mark.parametrize("objective", ["latency", "throughput"])
    def test_finds_bruteforce_optimum(self, objective):
        platform, graphs, model = tiny_problem()
        its, deps = [1, 2], [None, None]
        best = brute_force(platform, graphs, model, objective, 2, its, deps)
        tables = search_jax.build_tables(platform, graphs, model, 2,
                                         iterations=its)
        out = search_jax.anneal_search(tables, objective=objective, seed=0,
                                       population=64, steps=64, island=16)
        host = scalar_objective(platform, graphs, model, out.assignment,
                                objective, its, deps)
        assert host == pytest.approx(best, rel=1e-9, abs=1e-9)

    @pytest.mark.parametrize("path", FIXTURES, ids=lambda p: p.stem)
    def test_golden_fixtures_close_to_bb_and_never_worse_than_greedy(
            self, path):
        from repro.core import Plan
        from repro.core import solver_anneal, solver_greedy
        plan = Plan.load(path)
        req = plan.request
        sol = solver_anneal.solve(
            req.platform, list(req.graphs), req.model,
            objective=req.objective, max_transitions=req.max_transitions,
            iterations=list(req.iterations),
            depends_on=list(req.depends_on),
            population=1024, steps=192, evaluator="batch")
        greedy = solver_greedy.solve(
            req.platform, list(req.graphs), req.model,
            objective=req.objective, max_transitions=req.max_transitions,
            iterations=list(req.iterations),
            depends_on=list(req.depends_on), evaluator="batch")
        assert sol.objective <= greedy.objective + 1e-9
        # within 2% of the exact solver on every golden Table-6 scenario
        # (objectives may be negative: throughput is -fps).
        assert sol.objective <= plan.objective + 0.02 * abs(plan.objective)
        assert not sol.optimal
        assert sol.params["seed"] == 0


# ---------------------------------------------------------------------------
# validation and error surfaces
# ---------------------------------------------------------------------------

class TestValidation:
    @pytest.fixture(scope="class")
    def tables(self):
        platform, graphs, model = tiny_problem()
        return search_jax.build_tables(platform, graphs, model, 2)

    def test_rejects_unknown_objective(self, tables):
        with pytest.raises(ValueError, match="objective"):
            search_jax.anneal_search(tables, objective="energy")

    def test_rejects_unknown_precision(self, tables):
        with pytest.raises(ValueError, match="precision"):
            search_jax.anneal_search(tables, precision="bf16")

    def test_rejects_island_straddling_chunks(self, tables):
        with pytest.raises(ValueError, match="island"):
            search_jax.anneal_search(tables, island=32, chunk=48)

    def test_island_exceeding_population_names_nearest_legal(self, tables):
        with pytest.raises(ValueError, match="island=16"):
            search_jax.anneal_search(tables, population=16, island=32)

    def test_population_island_remainder_names_nearest_legal(self, tables):
        with pytest.raises(ValueError, match="population=96"):
            search_jax.anneal_search(tables, population=100, island=32)

    def test_chunk_exceeding_population_names_nearest_legal(self, tables):
        with pytest.raises(ValueError, match="chunk=64"):
            search_jax.anneal_search(tables, population=64, island=32,
                                     chunk=96)

    def test_rejects_illegal_init(self, tables):
        bad = np.zeros((tables.w, tables.gmax), dtype=np.int32)
        bad[0, 0] = 1  # transition budget: 3 groups alternating GPU/DLA
        bad[0, 2] = 1
        tables2 = search_jax.build_tables(*tiny_problem(),
                                          max_transitions=0)
        with pytest.raises(ValueError, match="legal"):
            search_jax.anneal_search(tables2, init_assignment=bad)

    def test_unlowerable_model_refused_with_guidance(self):
        platform, graphs, _model = tiny_problem()

        class Opaque:
            def slowdown(self, acc, own, ext):  # pragma: no cover
                return 1.0

        with pytest.raises(ValueError, match="surface"):
            search_jax.build_tables(platform, graphs, Opaque(), 2)

    def test_encode_decode_round_trip(self, tables):
        asg = (("GPU", "DLA", "DLA"), ("DLA", "GPU", "GPU"))
        row = tables.encode(asg)
        assert tables.decode(row) == asg
        assert tables.legal(row)


# ---------------------------------------------------------------------------
# wide sweep (scheduled lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestWideSweep:
    def test_table8_pairs_match_bb_within_2pct(self):
        from repro.core import Scheduler
        from repro.core import solver_anneal
        from benchmarks.table8_exhaustive import balanced_iterations
        sched = Scheduler("agx-orin")
        for pair in (["googlenet", "resnet18"], ["vgg19", "inception"],
                     ["caffenet", "resnet50"]):
            graphs = sched.graphs(pair)
            its = balanced_iterations(sched.platform, graphs)
            bb = sched.solve(graphs, solver="bb", max_transitions=2,
                             iterations=its)
            sol = solver_anneal.solve(
                sched.platform, graphs, sched.model,
                max_transitions=2, iterations=its,
                population=2048, steps=160, evaluator="batch")
            assert sol.objective <= bb.objective + 0.02 * abs(bb.objective)

    def test_chunk_invariance_at_scale(self):
        platform, graphs, model = xavier_pair()
        tables = search_jax.build_tables(platform, graphs, model, 2)
        kw = dict(objective="latency", seed=11, population=1024, steps=64)
        a = search_jax.anneal_search(tables, chunk=1024, **kw)
        b = search_jax.anneal_search(tables, chunk=256, **kw)
        assert a.assignment == b.assignment
        assert a.objective == b.objective
        assert a.chain == b.chain

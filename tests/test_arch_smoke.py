"""Per-architecture smoke tests (reduced configs, CPU, 1 device).

For every assigned arch: instantiate the reduced sibling config, run one
forward/train step, assert output shapes and finiteness.  For decode-capable
archs additionally check prefill+decode consistency: decoding token S after
a prefill of [0, S) must reproduce the full-sequence forward logits at
position S — this exercises every cache type (full KV, local ring buffer,
RG-LRU state + conv carry, RWKV matrix state + token shift).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build

ARCHS = list(configs.ARCHS)


def make_batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.embeds_only:
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            jnp.float32)
    else:
        batch["token_ids"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
        if cfg.mm_prefix:
            batch["mm_embeds"] = jax.random.normal(
                ks[1], (B, cfg.mm_prefix, cfg.mm_embed_dim), jnp.float32)
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab)
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get(arch).reduced()
            m = build(cfg, backend="xla")
            params = m.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, m, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = configs.get(arch)
    # exact numbers from the assignment table
    expect = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect
    if arch == "dbrx-132b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (16, 4)
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (128, 8)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(built, arch):
    cfg, m, params = built(arch)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: m.loss_fn(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: grad not finite"
    assert float(gnorm) > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_logit_shapes(built, arch):
    cfg, m, params = built(arch)
    B, S = 2, 16
    batch = make_batch(cfg, jax.random.PRNGKey(2), B, S)
    logits, _ = m.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


DECODE_ARCHS = [a for a in ARCHS if configs.get(a).has_decode]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_consistency(built, arch):
    """decode(token_S | prefill[0:S)) == forward[0:S+1)[S]."""
    cfg, m, params = built(arch)
    B, S = 2, 12
    key = jax.random.PRNGKey(3)
    full = make_batch(cfg, key, B, S + 1)
    prefix = dict(full)
    prefix.pop("labels")
    if cfg.embeds_only:
        pytest.skip("encoder-only")
    prefix["token_ids"] = full["token_ids"][:, :S]

    # ground truth: full forward, logits at position S
    logits_full, _ = m.forward(params, {k: v for k, v in full.items()
                                        if k != "labels"})
    want = logits_full[:, S]

    # prefill [0, S) then decode token S
    last_logits, caches = m.prefill(params, prefix)
    # prefill's last logits equal forward position S-1
    np.testing.assert_allclose(np.asarray(last_logits[:, 0]),
                               np.asarray(logits_full[:, S - 1]),
                               atol=2e-3, rtol=2e-3)
    step = {"token_ids": full["token_ids"][:, S:S + 1],
            "lengths": jnp.full((B,), S, jnp.int32)}
    got, _ = m.decode_step(params, caches, step)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", DECODE_ARCHS[:3])
def test_multi_step_decode_consistency(built, arch):
    """Three consecutive decode steps track the full forward."""
    cfg, m, params = built(arch)
    B, S, N = 1, 8, 3
    key = jax.random.PRNGKey(4)
    full = make_batch(cfg, key, B, S + N)
    ref_in = {k: v for k, v in full.items() if k != "labels"}
    logits_full, _ = m.forward(params, ref_in)

    prefix = {k: (v[:, :S] if k == "token_ids" else v)
              for k, v in ref_in.items()}
    _, caches = m.prefill(params, prefix)
    for t in range(N):
        step = {"token_ids": full["token_ids"][:, S + t:S + t + 1],
                "lengths": jnp.full((B,), S + t, jnp.int32)}
        got, caches = m.decode_step(params, caches, step)
        np.testing.assert_allclose(
            np.asarray(got[:, 0]), np.asarray(logits_full[:, S + t]),
            atol=3e-3, rtol=3e-3, err_msg=f"{arch} step {t}")


@pytest.mark.parametrize("arch", ARCHS)
def test_cell_support_matrix(arch):
    cfg = configs.get(arch)
    for shape in configs.SHAPES:
        ok, why = configs.cell_supported(cfg, shape)
        if shape == "train_4k" or shape == "prefill_32k":
            assert ok
        if shape == "long_500k":
            assert ok == (arch in ("recurrentgemma-9b", "rwkv6-7b")), why
        if shape == "decode_32k":
            assert ok == (arch != "hubert-xlarge")


def test_int8_kv_cache_decode_close(built):
    """int8-quantized KV cache stays within quantization tolerance."""
    import dataclasses
    cfg = configs.get("llama3.2-3b").reduced(n_layers=2)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m = build(cfg, backend="xla")
    m8 = build(cfg8, backend="xla")
    params = m.init(jax.random.PRNGKey(0))
    B, S = 1, 12
    full = make_batch(cfg, jax.random.PRNGKey(7), B, S + 1)
    prefix = {"token_ids": full["token_ids"][:, :S]}
    step = {"token_ids": full["token_ids"][:, S:S + 1],
            "lengths": jnp.full((B,), S, jnp.int32)}
    _, c32 = m.prefill(params, prefix)
    got32, _ = m.decode_step(params, c32, step)
    _, c8 = m8.prefill(params, prefix)
    got8, _ = m8.decode_step(params, c8, step)
    # int8 absmax quantization: ~1% relative error on logits
    np.testing.assert_allclose(np.asarray(got8), np.asarray(got32),
                               atol=0.15, rtol=0.1)
    assert c8["groups"][0]["k"]["data"].dtype == jnp.int8

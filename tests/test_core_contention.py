"""Property-based tests for the contention models (PCCS, §3.3).

Runs under hypothesis when installed; degrades to a deterministic example
grid otherwise (see tests/_prop.py).
"""
import pytest

from _prop import given, settings, st

from repro.core.contention import (PiecewiseModel, ProportionalShareModel,
                                   estimate_blackbox_demand, pccs_from_pairs)

demand = st.floats(min_value=0.0, max_value=1.5, allow_nan=False)


class TestProportionalShare:
    @given(own=demand, ext=demand)
    @settings(max_examples=200, deadline=None)
    def test_slowdown_at_least_one(self, own, ext):
        m = ProportionalShareModel()
        assert m.slowdown(own, ext) >= 1.0

    @given(own=demand, e1=demand, e2=demand)
    @settings(max_examples=200, deadline=None)
    def test_monotone_in_external(self, own, e1, e2):
        m = ProportionalShareModel()
        lo, hi = sorted([e1, e2])
        assert m.slowdown(own, lo) <= m.slowdown(own, hi) + 1e-12

    @given(own=demand, ext=demand)
    @settings(max_examples=200, deadline=None)
    def test_no_slowdown_under_capacity(self, own, ext):
        m = ProportionalShareModel(capacity=1.0)
        if own + ext <= 1.0:
            assert m.slowdown(own, ext) == 1.0

    def test_hand_value(self):
        m = ProportionalShareModel(capacity=1.0, sensitivity=1.0)
        # own 0.8, ext 0.8: dilation 1.6, boundedness 0.8 -> 1 + .8*.6
        assert m.slowdown(0.8, 0.8) == pytest.approx(1.48)

    def test_zero_demand_immune(self):
        m = ProportionalShareModel()
        assert m.slowdown(0.0, 5.0) == 1.0


class TestPiecewise:
    MODEL = PiecewiseModel(
        own_knots=(0.2, 0.5, 0.8),
        ext_knots=(0.2, 0.5, 0.8),
        table=((1.0, 1.05, 1.1),
               (1.05, 1.2, 1.4),
               (1.1, 1.4, 1.9)),
    )

    @given(own=demand, ext=demand)
    @settings(max_examples=200, deadline=None)
    def test_bounded_by_table(self, own, ext):
        s = self.MODEL.slowdown(own, ext)
        assert 1.0 <= s <= 1.9 + 1e-12

    def test_exact_at_knots(self):
        assert self.MODEL.slowdown(0.5, 0.5) == pytest.approx(1.2)
        assert self.MODEL.slowdown(0.8, 0.8) == pytest.approx(1.9)

    def test_bilinear_midpoint(self):
        # midpoint of the 4 central knots
        expect = (1.2 + 1.4 + 1.4 + 1.9) / 4
        assert self.MODEL.slowdown(0.65, 0.65) == pytest.approx(expect)

    def test_clamps_outside_grid(self):
        assert self.MODEL.slowdown(2.0, 2.0) == pytest.approx(1.9)
        assert self.MODEL.slowdown(0.01, 0.01) == pytest.approx(1.0)

    def test_validation(self):
        with pytest.raises(ValueError):
            PiecewiseModel((0.1,), (0.1,), ((0.5,),))   # slowdown < 1
        with pytest.raises(ValueError):
            PiecewiseModel((0.1, 0.2), (0.1,), ((1.0,),))


class TestBlackboxEstimation:
    def test_proportional_scaling(self):
        # §3.3: DSA demand = GPU demand * (EMC_dsa / EMC_gpu)
        assert estimate_blackbox_demand(0.6, 0.5, 0.25) == pytest.approx(0.3)

    def test_rejects_zero_util(self):
        with pytest.raises(ValueError):
            estimate_blackbox_demand(0.6, 0.0, 0.25)


class TestFitting:
    @given(data=st.lists(
        st.tuples(st.floats(0.05, 1.0), st.floats(0.05, 1.0),
                  st.floats(1.0, 3.0)),
        min_size=3, max_size=20))
    @settings(max_examples=50, deadline=None)
    def test_fit_produces_valid_model(self, data):
        m = pccs_from_pairs(data)
        for own in (0.1, 0.5, 0.9):
            for ext in (0.1, 0.5, 0.9):
                s = m.slowdown(own, ext)
                assert 1.0 <= s <= max(d[2] for d in data) + 1e-9

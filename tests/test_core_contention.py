"""Property-based + metamorphic tests for the contention models (PCCS, §3.3).

Runs under hypothesis when installed; degrades to a deterministic example
grid otherwise (see tests/_prop.py, which also hosts the shared model
strategies used here and by the batch/scalar differential suite).
"""
import pytest

from _prop import (contention_models, examples, given, piecewise_models,
                   proportional_models, settings, st)

from repro.core.contention import (PiecewiseModel, ProportionalShareModel,
                                   estimate_blackbox_demand, pccs_from_pairs)

demand = st.floats(min_value=0.0, max_value=1.5, allow_nan=False)


class TestProportionalShare:
    @given(own=demand, ext=demand)
    @settings(max_examples=examples(200), deadline=None)
    def test_slowdown_at_least_one(self, own, ext):
        m = ProportionalShareModel()
        assert m.slowdown(own, ext) >= 1.0

    @given(own=demand, e1=demand, e2=demand)
    @settings(max_examples=examples(200), deadline=None)
    def test_monotone_in_external(self, own, e1, e2):
        m = ProportionalShareModel()
        lo, hi = sorted([e1, e2])
        assert m.slowdown(own, lo) <= m.slowdown(own, hi) + 1e-12

    @given(own=demand, ext=demand)
    @settings(max_examples=examples(200), deadline=None)
    def test_no_slowdown_under_capacity(self, own, ext):
        m = ProportionalShareModel(capacity=1.0)
        if own + ext <= 1.0:
            assert m.slowdown(own, ext) == 1.0

    def test_hand_value(self):
        m = ProportionalShareModel(capacity=1.0, sensitivity=1.0)
        # own 0.8, ext 0.8: dilation 1.6, boundedness 0.8 -> 1 + .8*.6
        assert m.slowdown(0.8, 0.8) == pytest.approx(1.48)

    def test_zero_demand_immune(self):
        m = ProportionalShareModel()
        assert m.slowdown(0.0, 5.0) == 1.0


class TestPiecewise:
    MODEL = PiecewiseModel(
        own_knots=(0.2, 0.5, 0.8),
        ext_knots=(0.2, 0.5, 0.8),
        table=((1.0, 1.05, 1.1),
               (1.05, 1.2, 1.4),
               (1.1, 1.4, 1.9)),
    )

    @given(own=demand, ext=demand)
    @settings(max_examples=examples(200), deadline=None)
    def test_bounded_by_table(self, own, ext):
        s = self.MODEL.slowdown(own, ext)
        assert 1.0 <= s <= 1.9 + 1e-12

    def test_exact_at_knots(self):
        assert self.MODEL.slowdown(0.5, 0.5) == pytest.approx(1.2)
        assert self.MODEL.slowdown(0.8, 0.8) == pytest.approx(1.9)

    def test_bilinear_midpoint(self):
        # midpoint of the 4 central knots
        expect = (1.2 + 1.4 + 1.4 + 1.9) / 4
        assert self.MODEL.slowdown(0.65, 0.65) == pytest.approx(expect)

    def test_clamps_outside_grid(self):
        assert self.MODEL.slowdown(2.0, 2.0) == pytest.approx(1.9)
        assert self.MODEL.slowdown(0.01, 0.01) == pytest.approx(1.0)

    def test_validation(self):
        with pytest.raises(ValueError):
            PiecewiseModel((0.1,), (0.1,), ((0.5,),))   # slowdown < 1
        with pytest.raises(ValueError):
            PiecewiseModel((0.1, 0.2), (0.1,), ((1.0,),))


class TestMetamorphic:
    """Model-class-independent invariants over the shared strategies."""

    @given(model=contention_models(), own=demand, ext=demand)
    @settings(max_examples=examples(200), deadline=None)
    def test_slowdown_at_least_one(self, model, own, ext):
        assert model.slowdown(own, ext) >= 1.0 - 1e-12

    @given(model=contention_models(), own=demand, e1=demand, e2=demand)
    @settings(max_examples=examples(200), deadline=None)
    def test_monotone_nondecreasing_in_external(self, model, own, e1, e2):
        """More external traffic never speeds a layer up.  Holds for every
        ProportionalShareModel and for PiecewiseModels with monotone
        calibration tables (which the shared strategy guarantees — any
        physically meaningful PCCS surface is monotone)."""
        lo, hi = sorted([e1, e2])
        assert model.slowdown(own, lo) <= model.slowdown(own, hi) + 1e-9

    @given(model=contention_models(), own=demand)
    @settings(max_examples=examples(200), deadline=None)
    def test_alone_under_capacity_is_free(self, model, own):
        """slowdown(own, 0) == 1 while own demand fits the domain capacity:
        a layer running alone is never slowed down."""
        capacity = getattr(model, "capacity", 1.0)
        if own <= capacity:
            assert model.slowdown(own, 0.0) == pytest.approx(1.0)

    @given(model=piecewise_models(), own=demand)
    @settings(max_examples=examples(100), deadline=None)
    def test_piecewise_zero_external_is_exactly_one(self, model, own):
        # PCCS surfaces are only consulted under co-running traffic.
        assert model.slowdown(own, 0.0) == 1.0

    @given(model=proportional_models())
    @settings(max_examples=examples(50), deadline=None)
    def test_tabulated_piecewise_agrees_at_calibration_knots(self, model):
        """Sampling a ProportionalShareModel onto a PCCS knot grid must
        reproduce it exactly at the knots (bilinear interpolation is exact
        there) — the two model classes agree wherever they are calibrated
        to the same measurements."""
        knots = (0.2, 0.5, 0.8, 1.1)
        table = tuple(
            tuple(max(1.0, model.slowdown(o, e)) for e in knots)
            for o in knots)
        pw = PiecewiseModel(knots, knots, table)
        for o in knots:
            for e in knots:
                assert pw.slowdown(o, e) == pytest.approx(
                    max(1.0, model.slowdown(o, e)), abs=1e-9)

    @given(model=proportional_models(), o1=demand, o2=demand, ext=demand)
    @settings(max_examples=examples(200), deadline=None)
    def test_proportional_monotone_in_own_demand(self, model, o1, o2, ext):
        """A more bandwidth-hungry layer suffers at least as much from the
        same external traffic (boundedness and dilation both grow)."""
        lo, hi = sorted([o1, o2])
        if lo > 0.0:
            assert model.slowdown(lo, ext) <= model.slowdown(hi, ext) + 1e-9


class TestBlackboxEstimation:
    def test_proportional_scaling(self):
        # §3.3: DSA demand = GPU demand * (EMC_dsa / EMC_gpu)
        assert estimate_blackbox_demand(0.6, 0.5, 0.25) == pytest.approx(0.3)

    def test_rejects_zero_util(self):
        with pytest.raises(ValueError):
            estimate_blackbox_demand(0.6, 0.0, 0.25)


class TestFitting:
    @given(data=st.lists(
        st.tuples(st.floats(0.05, 1.0), st.floats(0.05, 1.0),
                  st.floats(1.0, 3.0)),
        min_size=3, max_size=20))
    @settings(max_examples=examples(50), deadline=None)
    def test_fit_produces_valid_model(self, data):
        m = pccs_from_pairs(data)
        for own in (0.1, 0.5, 0.9):
            for ext in (0.1, 0.5, 0.9):
                s = m.slowdown(own, ext)
                assert 1.0 <= s <= max(d[2] for d in data) + 1e-9

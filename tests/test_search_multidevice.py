"""Multi-device mesh fan-out of the device-resident schedule search.

The determinism contract (docs/architecture.md): for a fixed ``(seed,
population, island)`` the search incumbent is **bit-identical** across

* the legacy chunked driver (``devices=None``) and the mesh driver at
  ``devices=1`` with ``migrate="island"``;
* every device count at equal *total* population (ring migration is a
  pure gather whose seam permutes with the device order);
* the ``shard_map`` and ``pmap`` fan-outs;
* the select-kernel backends (``xla`` / ``pallas_interpret`` — the
  ``auto`` threshold is judged on the *global* lane count so the backend
  choice itself is device-count invariant).

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
mesh-smoke lane) the cross-device cases exercise real 8-way XLA
partitions; on a plain 1-device host they skip, and a subprocess test
(via :func:`repro.core.xla_env.subprocess_env`) still covers the
8-device path end-to-end.  The differential property re-checks the
scalar-simulator contract *under sharding* over the same seeded problem
generator as the single-device suite.
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

from _prop import examples, given, search_problems, settings

try:
    from repro.core import search_jax
    HAVE_JAX = search_jax.HAVE_JAX
except ImportError:  # pragma: no cover
    HAVE_JAX = False

pytestmark = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _device_count() -> int:
    import jax
    return jax.device_count()


def _outcome_key(out):
    return (out.assignment, out.objective, out.chain)


def xavier_tables():
    from repro.core import Scheduler
    sched = Scheduler("xavier-agx")
    return search_jax.build_tables(
        sched.platform, sched.graphs(["googlenet", "resnet18"]),
        sched.model, 2)


KW = dict(objective="latency", seed=7, population=64, steps=24,
          island=8, exchange_every=4)


@pytest.fixture(scope="module")
def tables():
    return xavier_tables()


class TestMeshMatchesLegacy:
    """devices=1 mesh path vs the pre-mesh chunked driver."""

    def test_island_migrate_bit_identical_to_chunked(self, tables):
        legacy = search_jax.anneal_search(tables, **KW)
        mesh = search_jax.anneal_search(tables, devices=1,
                                        migrate="island", **KW)
        assert _outcome_key(mesh) == _outcome_key(legacy)
        assert mesh.devices == 1 and mesh.migrate == "island"
        assert legacy.devices is None and legacy.fanout is None

    def test_ring_at_one_device_is_self_consistent(self, tables):
        a = search_jax.anneal_search(tables, devices=1, **KW)
        b = search_jax.anneal_search(tables, devices=1, migrate="ring",
                                     **KW)
        # migrate="auto" resolves to "ring" on the mesh path
        assert a.migrate == "ring"
        assert _outcome_key(a) == _outcome_key(b)

    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    def test_select_backend_invariance_on_mesh(self, tables, backend):
        ref = search_jax.anneal_search(tables, devices=1, **KW)
        out = search_jax.anneal_search(tables, devices=1, backend=backend,
                                       **KW)
        assert _outcome_key(out) == _outcome_key(ref)

    def test_compile_seconds_times_a_fresh_executable(self, tables):
        t = search_jax.compile_seconds(tables, objective="latency",
                                       population=64, devices=1)
        assert t > 0


class TestCrossDeviceDeterminism:
    """Equal total population, varying device count: bit-identical."""

    @pytest.fixture(scope="class")
    def ref(self, tables):
        return search_jax.anneal_search(tables, devices=1, **KW)

    @pytest.fixture(scope="class")
    def tables(self):
        return xavier_tables()

    @pytest.mark.parametrize("devices", [2, 4, 8])
    def test_device_count_invariance(self, tables, ref, devices):
        if _device_count() < devices:
            pytest.skip(f"needs {devices} jax devices "
                        f"(run under XLA_FLAGS="
                        f"--xla_force_host_platform_device_count=8)")
        out = search_jax.anneal_search(tables, devices=devices, **KW)
        assert _outcome_key(out) == _outcome_key(ref)
        assert out.devices == devices

    def test_pmap_matches_shard_map(self, tables, ref):
        if _device_count() < 2:
            pytest.skip("needs >= 2 jax devices")
        if not search_jax.HAVE_SHARD_MAP:
            pytest.skip("shard_map unavailable in this jax")
        sm = search_jax.anneal_search(tables, devices=2,
                                      fanout="shard_map", **KW)
        pm = search_jax.anneal_search(tables, devices=2, fanout="pmap",
                                      **KW)
        assert _outcome_key(sm) == _outcome_key(pm) == _outcome_key(ref)
        assert sm.fanout == "shard_map" and pm.fanout == "pmap"

    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    def test_backend_invariance_across_shards(self, tables, ref, backend):
        if _device_count() < 2:
            pytest.skip("needs >= 2 jax devices")
        out = search_jax.anneal_search(tables, devices=2, backend=backend,
                                       **KW)
        assert _outcome_key(out) == _outcome_key(ref)


# one subprocess emulating 8 host devices: covers the real multi-shard
# lowering even when this pytest process itself sees a single device.
_WORKER = textwrap.dedent("""\
    import json, sys
    sys.path.insert(0, {tests_dir!r})
    from test_search_multidevice import KW, xavier_tables, _outcome_key
    from repro.core import search_jax
    out = search_jax.anneal_search(xavier_tables(), devices=8, **KW)
    print(json.dumps({{"key": repr(_outcome_key(out)),
                       "fanout": out.fanout}}))
""")


def test_eight_emulated_devices_match_one(tables):
    from repro.core import xla_env
    ref = search_jax.anneal_search(tables, devices=1, **KW)
    env = xla_env.subprocess_env(8)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, "-c",
         _WORKER.format(tests_dir=str(ROOT / "tests"))],
        env=env, text=True, capture_output=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    got = json.loads(proc.stdout.strip().splitlines()[-1])
    assert got["key"] == repr(_outcome_key(ref))
    assert got["fanout"] in ("shard_map", "pmap")


class TestDifferentialUnderSharding:
    """The scalar-simulator contract holds for mesh incumbents too."""

    @given(prob=search_problems())
    @settings(max_examples=examples(4))
    def test_device_objective_matches_scalar_rerun(self, prob):
        from test_search import scalar_objective
        platform, graphs, model, its, deps, arr = prob
        mt = max(len(g) for g in graphs)
        tbl = search_jax.build_tables(
            platform, graphs, model, mt, iterations=its, depends_on=deps,
            arrival_ms=arr)
        ndev = min(_device_count(), 2)
        out = search_jax.anneal_search(
            tbl, objective="latency", seed=3, population=16 * ndev,
            steps=12, island=8, devices=ndev)
        host = scalar_objective(platform, graphs, model, out.assignment,
                                "latency", its, deps, arr)
        assert out.objective == pytest.approx(host, rel=1e-3, abs=1e-3)


class TestMeshKnobValidation:
    @pytest.fixture(scope="class")
    def tables(self):
        return xavier_tables()

    def test_devices_must_be_positive(self, tables):
        with pytest.raises(ValueError, match="devices"):
            search_jax.anneal_search(tables, devices=0, **KW)

    def test_devices_beyond_visible_names_xla_env(self, tables):
        with pytest.raises(ValueError, match="xla_env"):
            search_jax.anneal_search(tables, devices=4096, **KW)

    def test_unknown_migrate_lists_choices(self, tables):
        with pytest.raises(ValueError, match="island"):
            search_jax.anneal_search(tables, devices=1, migrate="bogus",
                                     **KW)

    def test_unknown_fanout_lists_choices(self, tables):
        with pytest.raises(ValueError, match="pmap"):
            search_jax.anneal_search(tables, devices=1, fanout="bogus",
                                     **KW)

    def test_fanout_without_devices_rejected(self, tables):
        with pytest.raises(ValueError, match="devices"):
            search_jax.anneal_search(tables, fanout="pmap", **KW)

    def test_ring_without_devices_rejected(self, tables):
        with pytest.raises(ValueError, match="migrate='island'"):
            search_jax.anneal_search(tables, migrate="ring", **KW)

    def test_population_quantum_names_nearest_legal(self, tables):
        kw = dict(KW, population=72)   # 72 % (8 islands * 2 devices) != 0
        if _device_count() < 2:
            pytest.skip("needs >= 2 jax devices")
        with pytest.raises(ValueError, match="population=64"):
            search_jax.anneal_search(tables, devices=2, **kw)

"""ProblemSpec lowering: identity, immutability, round-trip, surfaces.

The lowered array-IR (:mod:`repro.core.lowering`) is the contract between
problem construction and every fast evaluator backend; these tests pin:

* value-based identity — equal problems lowered independently hash and
  compare equal (specs key caches, e.g. compiled XLA executables);
* immutability — spec arrays are read-only;
* round-trip — ``lower -> simulate_spec`` equals simulating the original
  Workload objects directly, on 20 seeded scenarios and on specs emitted
  straight from the hypothesis strategy in ``tests/_prop.py``;
* surface lowering — built-in contention models lower to
  :class:`~repro.core.lowering.SlowdownSurface` parameters that reproduce
  their scalar ``slowdown``, scaled towers fold multiplicatively, and
  unknown models lower to None (NumPy fallback keeps working);
* evaluator lookup errors list the registered names
  (``Scheduler(evaluator=...)`` and the registry itself).
"""
from __future__ import annotations

import random

import numpy as np
import pytest

from _prop import (contention_models, examples, given, problem_specs,
                   random_scenario, settings, spec_from_seed, st)

from repro.core import registry
from repro.core.contention import PiecewiseModel, ProportionalShareModel
from repro.core.lowering import (ProblemSpec, SlowdownSurface, concat_specs,
                                 lower_surface, lower_workloads,
                                 surface_slowdown)
from repro.core.scheduler import Scheduler
from repro.core.simulate import simulate
from repro.core.simulate_batch import simulate_batch, simulate_spec


def _spec_for(seed: int) -> ProblemSpec:
    platform, wls, model = random_scenario(seed)
    return lower_workloads(platform, [wls], model)


class TestProblemSpecIdentity:
    def test_independent_lowerings_compare_and_hash_equal(self):
        for seed in range(8):
            a, b = _spec_for(seed), _spec_for(seed)
            assert a is not b
            assert a == b
            assert hash(a) == hash(b)
            assert a.content_hash() == b.content_hash()

    def test_hash_is_stable_within_process(self):
        spec = _spec_for(3)
        h = hash(spec)
        for _ in range(3):
            assert hash(spec) == h
            assert hash(_spec_for(3)) == h

    def test_distinct_problems_hash_differently(self):
        seen = {_spec_for(seed).content_hash() for seed in range(12)}
        assert len(seen) == 12

    def test_spec_usable_as_dict_key(self):
        cache = {_spec_for(5): "a"}
        assert cache[_spec_for(5)] == "a"
        assert _spec_for(6) not in cache

    def test_arrays_are_read_only(self):
        spec = _spec_for(1)
        for name in ("acc", "dur", "dem", "tau", "ngroups", "iters",
                     "dep", "arrival", "domshare", "model_of_acc"):
            arr = getattr(spec, name)
            with pytest.raises(ValueError):
                arr.reshape(-1)[:1] = 0

    def test_caller_owned_arrays_are_copied_not_frozen_in_place(self):
        """Constructing a spec from user buffers must neither freeze the
        caller's arrays nor alias them (mutations would corrupt the
        cached hash)."""
        import dataclasses
        base = _spec_for(4)
        mine = np.array(base.dur)        # writable caller-owned buffer
        spec = dataclasses.replace(base, dur=mine)
        assert mine.flags.writeable      # caller buffer untouched
        h = spec.content_hash()
        mine[:] = 0.0                    # caller keeps mutating their copy
        assert spec.content_hash() == h  # spec is isolated
        assert spec.dur is not mine

    def test_model_identity_participates(self):
        platform, wls, _ = random_scenario(9)
        a = lower_workloads(platform, [wls], ProportionalShareModel())
        b = lower_workloads(platform, [wls],
                            ProportionalShareModel(sensitivity=2.0))
        assert a != b
        assert a.content_hash() != b.content_hash()

    def test_concat_specs_matches_separate_runs(self):
        # two single-candidate specs over the same platform/model
        rng = random.Random(11)
        from _prop import random_model, random_platform, random_workloads
        platform = random_platform(rng)
        model = random_model(rng, platform)
        w1 = random_workloads(rng, platform)
        w2 = random_workloads(rng, platform)
        w = min(len(w1), len(w2))
        s1 = lower_workloads(platform, [w1[:w]], model)
        s2 = lower_workloads(platform, [w2[:w]], model)
        both = concat_specs([s1, s2])
        assert both.n == 2
        bt = simulate_spec(both)
        for i, s in enumerate((s1, s2)):
            one = simulate_spec(s)
            assert bt.makespan[i] == pytest.approx(one.makespan[0],
                                                   abs=1e-9)


class TestLoweringRoundTrip:
    def test_lower_then_simulate_equals_direct_simulate_20_seeds(self):
        for seed in range(20):
            platform, wls, model = random_scenario(seed)
            ref = simulate(platform, wls, model, record_timeline=False)
            res = simulate_spec(
                lower_workloads(platform, [wls], model)).result(0)
            assert res.makespan == pytest.approx(ref.makespan, abs=1e-6), seed
            assert res.finish_times == pytest.approx(ref.finish_times,
                                                     abs=1e-6), seed
            assert res.contention_ms == pytest.approx(ref.contention_ms,
                                                      abs=1e-6), seed

    def test_public_batch_wrapper_is_the_same_path(self):
        platform, wls, model = random_scenario(33)
        via_wrapper = simulate_batch(platform, [wls], model)
        via_spec = simulate_spec(lower_workloads(platform, [wls], model))
        assert via_wrapper.makespan == pytest.approx(via_spec.makespan)
        np.testing.assert_array_equal(via_wrapper.finish_times,
                                      via_spec.finish_times)

    @given(spec=problem_specs())
    @settings(max_examples=examples(25), deadline=None)
    def test_strategy_specs_simulate_consistently(self, spec):
        bt = simulate_spec(spec)
        assert len(bt) == spec.n
        assert np.isfinite(bt.makespan).all()
        assert (bt.makespan >= 0).all()
        # makespan is the max finish time by construction
        np.testing.assert_allclose(bt.makespan, bt.finish_times.max(axis=1))

    def test_strategy_emits_lowered_specs_directly(self):
        spec = spec_from_seed(17)
        assert isinstance(spec, ProblemSpec)
        assert spec.n >= 1 and spec.w >= 1
        assert len(spec.models) == len(spec.surfaces)


class TestSurfaceLowering:
    @given(model=contention_models(),
           own=st.floats(0.0, 1.5), ext=st.floats(0.0, 1.5))
    @settings(max_examples=examples(100), deadline=None)
    def test_surface_matches_scalar_model(self, model, own, ext):
        surface = lower_surface(model)
        assert surface is not None
        got = surface_slowdown(surface, np.array([own]), np.array([ext]))
        assert float(got[0]) == pytest.approx(model.slowdown(own, ext),
                                              abs=1e-12)

    def test_scaled_tower_folds_factors(self):
        from repro.core.dynamic import ScaledContentionModel
        base = ProportionalShareModel(capacity=1.0, sensitivity=2.0)
        tower = ScaledContentionModel(ScaledContentionModel(base, 1.5), 2.0)
        surface = lower_surface(tower)
        assert surface.kind == "proportional"
        assert surface.factor == pytest.approx(3.0)
        for own, ext in [(0.9, 0.9), (0.4, 1.1), (1.2, 0.3)]:
            got = surface_slowdown(surface, np.array([own]), np.array([ext]))
            assert float(got[0]) == pytest.approx(tower.slowdown(own, ext),
                                                  abs=1e-12)

    def test_unknown_model_lowers_to_none_but_numpy_still_works(self):
        class Odd:
            def slowdown(self, own, external):
                return 1.0 + own * external

        assert lower_surface(Odd()) is None
        platform, wls, _ = random_scenario(2)
        ref = simulate(platform, wls, Odd(), record_timeline=False)
        res = simulate_batch(platform, [wls], Odd()).result(0)
        assert res.makespan == pytest.approx(ref.makespan, abs=1e-6)

    def test_scaled_of_opaque_base_lowers_to_none(self):
        from repro.core.dynamic import ScaledContentionModel

        class Odd:
            def slowdown(self, own, external):
                return 1.0 + own * external

        assert lower_surface(ScaledContentionModel(Odd(), 2.0)) is None

    def test_scaled_wrapper_keeps_third_party_vectorized_fast_path(self):
        """§4.4 rescaling must not drop a register_vectorized_slowdown
        model to the elementwise fallback (scalar .slowdown per float)."""
        from repro.core.dynamic import ScaledContentionModel
        from repro.core.lowering import (register_vectorized_slowdown,
                                         slowdown_array)

        calls = {"vec": 0}

        class Third:
            def slowdown(self, own, external):
                raise AssertionError("elementwise fallback reached")

        def vec(m, own, ext):
            calls["vec"] += 1
            return 1.0 + 0.5 * np.asarray(own) * np.asarray(ext)

        register_vectorized_slowdown(Third, vec)
        wrapped = ScaledContentionModel(Third(), 2.0)
        own = np.array([0.4, 0.9])
        ext = np.array([0.8, 0.2])
        got = slowdown_array(wrapped, own, ext)
        assert calls["vec"] == 1
        np.testing.assert_allclose(got, 1.0 + 2.0 * (vec(None, own, ext)
                                                     - 1.0))

    def test_scaled_vectorized_path_matches_surface_path(self):
        from repro.core.dynamic import ScaledContentionModel
        from repro.core.lowering import slowdown_array
        m = ScaledContentionModel(
            ProportionalShareModel(capacity=1.0, sensitivity=2.0), 1.75)
        own = np.array([0.2, 0.9, 1.2])
        ext = np.array([0.9, 0.9, 0.3])
        got = slowdown_array(m, own, ext)
        want = [m.slowdown(o, e) for o, e in zip(own, ext)]
        np.testing.assert_allclose(got, want, atol=1e-12)

    def test_concat_specs_rejects_mismatched_models(self):
        platform, wls, _ = random_scenario(21)
        a = lower_workloads(platform, [wls], ProportionalShareModel())
        b = lower_workloads(platform, [wls],
                            ProportionalShareModel(sensitivity=2.5))
        with pytest.raises(ValueError, match="contention model"):
            concat_specs([a, b])

    def test_piecewise_surface_kind(self):
        m = PiecewiseModel((0.2, 0.6, 1.0), (0.2, 0.6, 1.0),
                           ((1.0, 1.1, 1.3), (1.1, 1.4, 1.7),
                            (1.3, 1.7, 2.2)))
        s = lower_surface(m)
        assert s == SlowdownSurface("piecewise", own_knots=m.own_knots,
                                    ext_knots=m.ext_knots, table=m.table)


class TestEvaluatorLookupErrors:
    def test_registry_lists_names_on_unknown_evaluator(self):
        with pytest.raises(KeyError) as ei:
            registry.get_evaluator("nope")
        msg = str(ei.value)
        for name in ("batch", "scalar", "jax", "auto"):
            assert name in msg

    def test_scheduler_ctor_rejects_unknown_evaluator_with_names(self):
        with pytest.raises(KeyError) as ei:
            Scheduler("agx-orin", evaluator="does-not-exist")
        assert "registered evaluators" in str(ei.value)
        assert "batch" in str(ei.value)

    def test_jax_evaluator_is_registered_and_auto_stays_batch(self):
        assert "jax" in registry.evaluator_names()
        assert registry.resolve_evaluator("auto").name == "batch"

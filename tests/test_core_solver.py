"""Solver correctness: optimality, never-worse guarantee, encoding agreement."""
import random

import pytest

from repro.core import api, solver_bb, solver_z3
from repro.core.accelerators import Accelerator, Platform
from repro.core.baselines import BASELINES
from repro.core.contention import ProportionalShareModel
from repro.core.dynamic import DHaXCoNN
from repro.core.graph import DNNGraph, LayerGroup
from repro.core.simulate import simulate

MODEL = ProportionalShareModel(capacity=1.0, sensitivity=1.5)


def rand_platform(rng):
    return Platform(
        name="rand",
        accelerators=(
            Accelerator("A", 1e12, 100e9, transition_in_ms=0.01,
                        transition_out_ms=0.01),
            Accelerator("B", 1e12, 100e9, transition_in_ms=0.02,
                        transition_out_ms=0.02),
        ),
        transition_bw=100e9,
        domains={"EMC": ("A", "B")},
        domain_bw={"EMC": 100e9},
    )


def rand_graph(rng, name, n):
    groups = []
    for i in range(n):
        ta = rng.uniform(0.1, 2.0)
        ratio = rng.uniform(1.1, 3.0)
        da = rng.uniform(0.2, 0.9)
        groups.append(LayerGroup(
            name=f"{name}{i}",
            times={"A": ta, "B": ta * ratio},
            mem_demand={"A": da, "B": da * ta / (ta * ratio)},
            out_bytes=rng.uniform(0, 2e6),
            can_transition_after=rng.random() > 0.2,
        ))
    return DNNGraph(name, tuple(groups))


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("objective", ["latency", "throughput"])
def test_z3_matches_bb_oracle(seed, objective):
    """CEGAR-Z3 and exhaustive branch&bound find the same optimum."""
    rng = random.Random(seed)
    plat = rand_platform(rng)
    graphs = [rand_graph(rng, "n1", rng.randint(3, 5)),
              rand_graph(rng, "n2", rng.randint(3, 5))]
    bb = solver_bb.solve(plat, graphs, MODEL, objective, max_transitions=2)
    z = solver_z3.solve(plat, graphs, MODEL, objective, max_transitions=2)
    assert z.optimal
    assert z.objective == pytest.approx(bb.objective, rel=1e-6)


@pytest.mark.parametrize("seed", range(6))
def test_never_worse_than_baselines(seed):
    """§5.2: HaX-CoNN falls back to the baseline when no split helps."""
    rng = random.Random(100 + seed)
    plat = rand_platform(rng)
    graphs = [rand_graph(rng, "n1", rng.randint(3, 6)),
              rand_graph(rng, "n2", rng.randint(3, 6))]
    sol = solver_z3.solve(plat, graphs, MODEL, "latency", max_transitions=2)
    for name, fn in BASELINES.items():
        wls = fn(plat, graphs)
        res = simulate(plat, wls, MODEL)
        assert sol.objective <= res.objective("latency") + 1e-9, name


@pytest.mark.skipif(not solver_z3.HAVE_Z3, reason="z3 not installed")
def test_monolithic_agrees_with_cegar():
    """The paper's direct Eq. 1-11 encoding lands near the exact optimum.

    The monolithic encoding linearizes contention per overlap interval
    (dur = t + Σ overlap·(s-1)) whereas the simulator integrates rates, so
    the two disagree by the linearization error; the monolithic schedule
    re-evaluated under the exact model must stay within 15% of the CEGAR
    optimum (and is exactly optimal under its own timing model).
    """
    plat = api.resolve_platform("xavier-agx")
    graphs = api.resolve_graphs(["vgg19", "resnet101"], plat)
    merged = [g.merged(list(range(1, len(g), 3))) for g in graphs]
    m = api.default_model(plat)
    mono = solver_z3.solve_monolithic(plat, merged, m, "latency",
                                      max_transitions=1, timeout_s=120)
    ceg = solver_z3.solve(plat, merged, m, "latency", max_transitions=1)
    assert ceg.objective <= mono.objective + 1e-9
    assert mono.objective <= ceg.objective * 1.15


def test_respects_transition_legality():
    rng = random.Random(7)
    plat = rand_platform(rng)
    groups = [
        LayerGroup("a", {"A": 1.0, "B": 1.2}, {"A": 0.5, "B": 0.4},
                   can_transition_after=False),
        LayerGroup("b", {"A": 1.0, "B": 0.2}, {"A": 0.5, "B": 0.4}),
    ]
    g1 = DNNGraph("n1", tuple(groups))
    g2 = rand_graph(rng, "n2", 3)
    sol = solver_z3.solve(plat, [g1, g2], MODEL, "latency")
    a = sol.assignments[0]
    assert a[0] == a[1]     # illegal boundary collapsed


def test_max_transitions_respected():
    rng = random.Random(11)
    plat = rand_platform(rng)
    graphs = [rand_graph(rng, "n1", 6), rand_graph(rng, "n2", 6)]
    sol = solver_z3.solve(plat, graphs, MODEL, "latency", max_transitions=1)
    for asg in sol.assignments:
        trans = sum(1 for i in range(len(asg) - 1) if asg[i] != asg[i + 1])
        assert trans <= 1


def test_heterogeneous_support_matrix():
    """A DNN lacking DLA support (DenseNet on Xavier) must stay on GPU."""
    plat = api.resolve_platform("xavier-agx")
    graphs = api.resolve_graphs(["densenet", "resnet18"], plat)
    sol = solver_z3.solve(plat, graphs, api.default_model(plat), "latency",
                          max_transitions=2)
    assert all(a == "GPU" for a in sol.assignments[0])


class TestDynamic:
    def test_monotone_improvement_and_convergence(self):
        plat = api.resolve_platform("xavier-agx")
        graphs = api.resolve_graphs(["vgg19", "resnet101"], plat)
        m = api.default_model(plat)
        d = DHaXCoNN(plat, graphs, m, "latency", max_transitions=2)
        objs = [d.best.objective]
        for _ in range(40):
            d.step(0.25)
            objs.append(d.best.objective)
            if d.converged:
                break
        assert d.converged
        assert all(b <= a + 1e-12 for a, b in zip(objs, objs[1:]))
        bb = solver_bb.solve(plat, graphs, m, "latency", max_transitions=2)
        assert d.best.objective == pytest.approx(bb.objective, rel=1e-6)

    def test_initial_schedule_is_best_naive(self):
        plat = api.resolve_platform("xavier-agx")
        graphs = api.resolve_graphs(["googlenet", "resnet152"], plat)
        m = api.default_model(plat)
        d = DHaXCoNN(plat, graphs, m, "latency")
        base = min(
            simulate(plat, fn(plat, graphs), m).objective("latency")
            for fn in BASELINES.values())
        assert d.best.objective == pytest.approx(base, rel=1e-9)

"""Fleet serving subsystem: trace generators, SLO admission, the
virtual-time gateway loop, §4.4 under bursty arrivals, and the sharded
plan-cache cold start."""
import asyncio
import json

import numpy as np
import pytest

from repro import configs
from repro.core.accelerators import tpu_pod_split
from repro.core.plan import ShardedPlanCache
from repro.serve.engine import METRIC_KEYS
from repro.serve.fleet import (SLO, AdmissionController, ArrivalTrace,
                               FleetConfig, FleetGateway, build_pool,
                               bursty_trace, diurnal_trace, parse_slo,
                               parse_trace_spec, poisson_trace, serve_async)
from repro.serve.gateway import GatewayConfig, TenantSpec

from _prop import arrival_traces, examples, given, settings

STABLE = configs.get("stablelm-1.6b")
LLAMA = configs.get("llama3.2-3b")


def _specs():
    # full-size configs: the fleet loop prices service from the solved
    # schedule and never instantiates the models.
    return [TenantSpec("stable", STABLE, max_slots=2, capacity=256,
                       prompt_len=64, max_new=16),
            TenantSpec("llama", LLAMA, max_slots=2, capacity=256,
                       prompt_len=64, max_new=16)]


@pytest.fixture(scope="module")
def pool():
    gcfg = GatewayConfig(max_transitions=1, body_groups=1)
    plats = [tpu_pod_split(1, 3, name="p13"),
             tpu_pod_split(2, 2, name="p22")]
    return build_pool(_specs(), plats, gcfg, slots=4, deadline_s=5.0)


# ---------------------------------------------------------------------------
# traffic
# ---------------------------------------------------------------------------

class TestTraces:
    def test_bit_deterministic_per_seed(self):
        a = poisson_trace(100.0, 300, 20, seed=3, skew=1.0)
        b = poisson_trace(100.0, 300, 20, seed=3, skew=1.0)
        for col in ("t_ms", "tenant", "prompt_len", "max_new"):
            assert np.array_equal(getattr(a, col), getattr(b, col))
        c = poisson_trace(100.0, 300, 20, seed=4, skew=1.0)
        assert not np.array_equal(a.t_ms, c.t_ms)

    def test_json_round_trip_is_byte_stable(self):
        tr = bursty_trace(50.0, 500.0, 200, 10, seed=9)
        blob = tr.to_json()
        again = ArrivalTrace.from_json(blob)
        assert again.to_json() == blob
        assert again.trace_hash() == tr.trace_hash()

    def test_save_load(self, tmp_path):
        tr = diurnal_trace(200.0, 150, 30, seed=2, day_s=60.0)
        path = tr.save(tmp_path / "trace.json")
        again = ArrivalTrace.load(path)
        assert np.array_equal(again.t_ms, tr.t_ms)
        assert again.params == tr.params

    def test_bursty_is_burstier_than_poisson(self):
        po = poisson_trace(100.0, 2000, 10, seed=0)
        bu = bursty_trace(20.0, 2000.0, 2000, 10, seed=0,
                          mean_calm_s=10.0, mean_burst_s=0.5)
        assert bu.burstiness() > po.burstiness() > 0.5

    def test_mean_rate_tracks_parameter(self):
        tr = poisson_trace(250.0, 5000, 10, seed=1)
        assert tr.mean_rate_rps == pytest.approx(250.0, rel=0.1)

    def test_skew_concentrates_tenants(self):
        flat = poisson_trace(100.0, 3000, 50, seed=5, skew=0.0)
        skew = poisson_trace(100.0, 3000, 50, seed=5, skew=2.0)
        top = lambda t: np.bincount(t.tenant, minlength=50).max()
        assert top(skew) > 2 * top(flat)

    def test_arrays_are_frozen(self):
        tr = poisson_trace(10.0, 10, 2, seed=0)
        with pytest.raises(ValueError):
            tr.t_ms[0] = -1.0

    def test_validation(self):
        with pytest.raises(ValueError, match="non-decreasing"):
            ArrivalTrace("custom", 0, 2, {}, np.array([2.0, 1.0]),
                         np.zeros(2, np.int32), np.ones(2, np.int32),
                         np.ones(2, np.int32))
        with pytest.raises(ValueError, match="tenant"):
            ArrivalTrace("custom", 0, 2, {}, np.array([1.0, 2.0]),
                         np.array([0, 5], np.int32), np.ones(2, np.int32),
                         np.ones(2, np.int32))
        with pytest.raises(ValueError, match="format"):
            ArrivalTrace.from_dict({"format": 99})

    def test_parse_trace_spec_generator_and_file(self, tmp_path):
        tr = parse_trace_spec("poisson:rate=100,n=50,tenants=8,seed=3")
        assert tr.kind == "poisson" and len(tr) == 50 and tr.seed == 3
        path = tr.save(tmp_path / "t.json")
        again = parse_trace_spec(str(path))
        assert again.trace_hash() == tr.trace_hash()

    def test_parse_trace_spec_errors(self):
        with pytest.raises(ValueError, match="kind"):
            parse_trace_spec("weird:rate=1")
        with pytest.raises(ValueError, match="missing"):
            parse_trace_spec("bursty:base=10,n=100,tenants=4")

    @settings(max_examples=examples(20))
    @given(trace=arrival_traces())
    def test_trace_invariants(self, trace):
        assert np.all(np.diff(trace.t_ms) >= 0.0)
        assert trace.t_ms[0] >= 0.0
        assert trace.tenant.min() >= 0
        assert trace.tenant.max() < trace.n_tenants
        assert trace.prompt_len.min() >= 1 and trace.max_new.min() >= 1
        again = ArrivalTrace.from_json(trace.to_json())
        assert again.trace_hash() == trace.trace_hash()


# ---------------------------------------------------------------------------
# SLO + admission
# ---------------------------------------------------------------------------

class TestSLO:
    def test_parse_slo(self):
        slo = parse_slo("p99=400,rps=5,priority=2")
        assert slo == SLO(p99_ms=400.0, throughput_rps=5.0, priority=2.0)
        assert parse_slo("p99=100") == SLO(p99_ms=100.0)
        with pytest.raises(ValueError, match="p99"):
            parse_slo("rps=5")
        with pytest.raises(ValueError, match="unknown"):
            parse_slo("p99=100,latency=5")

    def test_slo_validation(self):
        with pytest.raises(ValueError):
            SLO(p99_ms=0.0)
        with pytest.raises(ValueError):
            SLO(p99_ms=10.0, priority=0.0)

    def test_kv_budget_acquire_release(self):
        ac = AdmissionController(budget_bytes=100.0)
        assert ac.try_acquire(60.0) and ac.try_acquire(40.0)
        assert not ac.try_acquire(1.0)
        assert ac.deferred == 1
        ac.release(40.0)
        assert ac.try_acquire(1.0)

    def test_should_shed_on_queue_bound_and_wait(self):
        ac = AdmissionController(default_slo=SLO(p99_ms=100.0),
                                 max_queue_per_tenant=2, shed_factor=2.0)
        assert not ac.should_shed(0, queue_depth=1, est_wait_ms=10.0)
        assert ac.should_shed(0, queue_depth=2, est_wait_ms=10.0)
        assert ac.should_shed(0, queue_depth=0, est_wait_ms=500.0)
        assert ac.shed == 2

    def test_priority_tolerates_deeper_backlog(self):
        ac = AdmissionController(
            default_slo=SLO(p99_ms=100.0),
            slos={1: SLO(p99_ms=100.0, priority=4.0)}, shed_factor=2.0)
        assert ac.should_shed(0, 0, est_wait_ms=500.0)       # default sheds
        assert not ac.should_shed(1, 0, est_wait_ms=500.0)   # priority holds

    def test_select_plan_earliest_finish(self):
        ac = AdmissionController()
        assert ac.select_plan([100.0, 0.0], [10.0, 50.0]) == 1
        assert ac.select_plan([10.0, 0.0], [10.0, 50.0]) == 0

    def test_engine_gate_wires_into_serving_engine(self):
        import jax
        from repro.models import build
        from repro.serve.engine import ServingEngine
        cfg = STABLE.reduced()
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ac = AdmissionController(budget_bytes=0.0)    # nothing fits
        eng = ServingEngine(model, params, max_slots=2, capacity=32,
                            admission_gate=ac.engine_gate(64.0))
        eng.submit(np.arange(5), max_new=2)
        assert eng.step() == 0 and eng.active == 0    # deferred, not lost
        assert ac.deferred >= 1 and eng.counters.deferred >= 1
        ac.budget_bytes = None                        # budget lifted
        eng.run_until_drained()
        assert len(eng.completed) == 1


# ---------------------------------------------------------------------------
# fleet loop
# ---------------------------------------------------------------------------

class TestFleetLoop:
    def test_replay_conserves_requests(self, pool):
        tr = poisson_trace(300.0, 800, 50, seed=4)
        gw = FleetGateway(pool, n_tenants=50, capacity_hint=len(tr))
        rep = gw.replay(tr)
        assert rep.n_requests == len(tr)
        assert rep.completed + rep.shed == rep.n_requests
        assert rep.completed == rep.n_requests       # light load: no shed
        assert np.all(rep.latency_ms >= 0.0)
        assert np.all(rep.slowdown >= 1.0 - 1e-9)

    def test_replay_is_deterministic(self, pool):
        tr = bursty_trace(100.0, 900.0, 600, 40, seed=8)
        reps = []
        for _ in range(2):
            gw = FleetGateway(pool, n_tenants=40, capacity_hint=len(tr))
            reps.append(gw.replay(tr))
        assert np.array_equal(reps[0].t_end, reps[1].t_end)
        assert np.array_equal(reps[0].plan, reps[1].plan)

    def test_telemetry_canonical_shape(self, pool):
        tr = poisson_trace(200.0, 200, 10, seed=2)
        gw = FleetGateway(pool, n_tenants=10, capacity_hint=len(tr))
        gw.replay(tr)
        m = gw.metrics()
        assert set(m) >= {"steps", "kv_bytes_in_use",
                          "deferred_admissions", "reschedules", "tenants"}
        for row in m["tenants"].values():
            assert tuple(row) == METRIC_KEYS

    def test_slo_routing_no_worse_than_round_robin_on_p99(self, pool):
        tr = bursty_trace(150.0, 1200.0, 4000, 200, seed=7)
        p99 = {}
        for policy in ("slo", "round_robin"):
            gw = FleetGateway(pool, n_tenants=200,
                              cfg=FleetConfig(policy=policy),
                              capacity_hint=len(tr))
            p99[policy] = gw.replay(tr).p99_ms
        assert p99["slo"] <= p99["round_robin"] + 1e-9

    def test_kv_budget_defers_but_completes(self, pool):
        kv = float(max(pp.kv_bytes.max() for pp in pool))
        tr = poisson_trace(400.0, 400, 20, seed=6)
        gw = FleetGateway(
            pool, n_tenants=20,
            cfg=FleetConfig(memory_budget_bytes=2.0 * kv),
            capacity_hint=len(tr))
        rep = gw.replay(tr)
        assert rep.deferred > 0                      # budget throttled starts
        assert rep.completed == rep.n_requests       # but nothing was lost

    def test_overload_sheds_and_conserves(self, pool):
        tr = poisson_trace(5000.0, 3000, 10, seed=1)
        gw = FleetGateway(
            pool, n_tenants=10,
            cfg=FleetConfig(default_slo=SLO(p99_ms=50.0),
                            max_queue_per_tenant=8, shed_factor=1.0),
            capacity_hint=len(tr))
        rep = gw.replay(tr)
        assert rep.shed > 0
        assert rep.completed + rep.shed == rep.n_requests
        assert rep.slo_report()["shed"] == rep.shed

    def test_time_cannot_go_backwards(self, pool):
        gw = FleetGateway(pool, n_tenants=4)
        gw.submit(100.0, 0, 4)
        with pytest.raises(ValueError, match="backwards"):
            gw.submit(50.0, 1, 4)

    def test_pool_class_mismatch_rejected(self, pool):
        with pytest.raises(ValueError, match="n_tenants"):
            FleetGateway(pool, n_tenants=0)

    def test_async_front_end_matches_replay_counts(self, pool):
        tr = poisson_trace(500.0, 80, 10, seed=3)
        gw = FleetGateway(pool, n_tenants=10, capacity_hint=len(tr))
        rep = asyncio.run(serve_async(gw, tr))
        assert rep.completed == len(tr)
        gw2 = FleetGateway(pool, n_tenants=10, capacity_hint=len(tr))
        rep2 = gw2.replay(tr)
        assert rep.completed == rep2.completed
        assert np.array_equal(rep.t_end, rep2.t_end)  # same virtual machine


# ---------------------------------------------------------------------------
# §4.4 under bursty arrivals (satellite)
# ---------------------------------------------------------------------------

class TestDynamicRescheduling:
    def test_contention_burst_fires_monitor_and_converges(self, pool):
        tr = bursty_trace(150.0, 1200.0, 3000, 100, seed=5)
        mid = float(tr.t_ms[len(tr) // 4])
        gw = FleetGateway(
            pool, n_tenants=100,
            cfg=FleetConfig(default_slo=SLO(p99_ms=10_000.0),
                            slowdown_threshold=1.3, patience=4,
                            cooldown=64, warmup=0),
            capacity_hint=len(tr))
        rep = gw.replay(tr, contention_events=[(mid, 0, 4.0)])
        # the monitor fired and the gateway re-solved under the observed
        # severity (§4.4)
        assert rep.reschedules
        ev = rep.reschedules[0]
        assert ev.plan == pool[0].name
        assert ev.observed_factor > 1.3
        # adopt-if-better: the re-solve never replaces the incumbent with
        # a worse schedule under the same scaled model
        for e in rep.reschedules:
            assert e.new_objective <= e.old_objective + 1e-9
        # no admitted tenant was dropped by the adaptation
        assert rep.completed + rep.shed == rep.n_requests
        assert rep.completed == rep.n_requests

    def test_reschedules_at_same_severity_are_plan_cache_hits(self, pool):
        tr = bursty_trace(150.0, 1200.0, 3000, 100, seed=5)
        mid = float(tr.t_ms[len(tr) // 4])
        gw = FleetGateway(
            pool, n_tenants=100,
            cfg=FleetConfig(default_slo=SLO(p99_ms=10_000.0),
                            slowdown_threshold=1.3, patience=4,
                            cooldown=64, warmup=0),
            capacity_hint=len(tr))
        sched = pool[0].scheduler
        hits_before, solves_before = sched.cache.hits, sched.solves
        rep = gw.replay(tr, contention_events=[(mid, 0, 4.0)])
        assert len(rep.reschedules) >= 2
        # repeated fires at the same quantized severity re-solve at most
        # once; the rest route through the plan cache
        assert sched.solves - solves_before <= 2
        assert sched.cache.hits > hits_before

    def test_clearing_contention_restores_steady_state(self, pool):
        pp = pool[1]
        base = pp.step_ms.copy()
        pp.apply_factor(3.0)
        assert np.allclose(pp.step_ms, 3.0 * base)
        pp.apply_factor(1.0)
        assert np.allclose(pp.step_ms, base)


# ---------------------------------------------------------------------------
# sharded plan cache cold start
# ---------------------------------------------------------------------------

class TestColdStart:
    def test_pool_boots_from_sharded_cache_with_zero_solves(self, tmp_path):
        gcfg = GatewayConfig(max_transitions=1, body_groups=1)
        plats = [tpu_pod_split(1, 3, name="p13"),
                 tpu_pod_split(2, 2, name="p22")]
        cache1 = ShardedPlanCache(tmp_path / "plans")
        pool1 = build_pool(_specs(), plats, gcfg, cache1, slots=4,
                           deadline_s=5.0)
        assert sum(pp.scheduler.solves for pp in pool1) == len(plats)
        assert cache1.disk_entries() == len(plats)
        # fresh cache objects + fresh schedulers: pure disk loads
        cache2 = ShardedPlanCache(tmp_path / "plans")
        pool2 = build_pool(_specs(), plats, gcfg, cache2, slots=4,
                           deadline_s=5.0)
        assert sum(pp.scheduler.solves for pp in pool2) == 0
        for a, b in zip(pool1, pool2):
            assert np.allclose(a.step_ms, b.step_ms)

"""Differential property tests: batch == scalar == jax simulators.

The scalar event-driven simulator (:mod:`repro.core.simulate`) is the
authoritative evaluator of the paper's Eq. 2-8 timeline; the vectorized
batch evaluator (:mod:`repro.core.simulate_batch`) must agree with it within
1e-6 on every observable — makespan, per-workload finish times and
per-iteration latencies, the contention-interval integral (``contention_ms``
= Σ (1 - 1/s)·len) and per-accelerator busy time — across randomly generated
platforms, graphs, assignments, transition delays, ``depends_on`` pipelines,
``arrival_ms`` offsets and multi-iteration workloads.  The XLA evaluator
(:mod:`repro.core.simulate_jax`, ``evaluator="jax"``) is held to the same
observables at 1e-5 (its float64 mode is ~1e-12 from the NumPy path in
practice; the looser bound is the cross-backend contract on float32-safe
inputs), on the random corpus *and* on the three golden Table-6 plan
fixtures.

Scenarios are generated from a seeded ``random.Random`` (shared generators
in ``tests/_prop.py``) so the property is "for any seed, all backends agree
on the scenario derived from that seed": deterministic under the fallback
grid, fully explorable under hypothesis (``HYPOTHESIS_PROFILE=thorough``
raises the example count in the scheduled CI job).
"""
from __future__ import annotations

import random

import numpy as np
import pytest

from _prop import (contention_models, examples, given, problem_specs,
                   random_model, random_platform, random_scenario,
                   random_workloads, settings, st)

from repro.core.accelerators import Accelerator, Platform
from repro.core.contention import PiecewiseModel, ProportionalShareModel
from repro.core.graph import DNNGraph, LayerGroup
from repro.core.simulate import Workload, simulate
from repro.core.simulate_batch import (simulate_assignments, simulate_batch,
                                       slowdown_array)

TOL = 1e-6
#: the jax evaluator's cross-backend contract (float32-safe inputs).
JAX_TOL = 1e-5

try:
    from repro.core import simulate_jax
    HAVE_JAX = simulate_jax.HAVE_JAX
except ImportError:  # pragma: no cover
    HAVE_JAX = False

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


def assert_equivalent(ref, res, context="", tol=TOL):
    __tracebackhide__ = True
    assert res.makespan == pytest.approx(ref.makespan, abs=tol), context
    assert res.finish_times == pytest.approx(ref.finish_times, abs=tol), \
        context
    assert len(res.iteration_latencies) == len(ref.iteration_latencies)
    for a, b in zip(res.iteration_latencies, ref.iteration_latencies):
        assert a == pytest.approx(b, abs=tol), context
    assert res.contention_ms == pytest.approx(ref.contention_ms, abs=tol), \
        context
    for acc, t in ref.busy_ms.items():
        assert res.busy_ms[acc] == pytest.approx(t, abs=tol), context


# ---------------------------------------------------------------------------
# the differential property
# ---------------------------------------------------------------------------

class TestDifferential:
    @given(seed=st.integers(min_value=0, max_value=10_000_000))
    @settings(max_examples=examples(200), deadline=None)
    def test_batch_matches_scalar_on_random_scenarios(self, seed):
        platform, wls, model = random_scenario(seed)
        ref = simulate(platform, wls, model, record_timeline=False)
        res = simulate_batch(platform, [wls], model).result(0)
        assert_equivalent(ref, res, f"seed={seed}")

    @given(seed=st.integers(min_value=0, max_value=1_000_000))
    @settings(max_examples=examples(25), deadline=None)
    def test_candidates_in_one_batch_are_independent(self, seed):
        """A population must score each member exactly as it would alone."""
        rng = random.Random(seed)
        platform = random_platform(rng)
        model = random_model(rng, platform)
        batch = [random_workloads(rng, platform) for _ in range(8)]
        w = min(len(b) for b in batch)
        batch = [b[:w] for b in batch]
        bt = simulate_batch(platform, batch, model)
        for i, wls in enumerate(batch):
            ref = simulate(platform, wls, model, record_timeline=False)
            assert_equivalent(ref, bt.result(i), f"seed={seed} cand={i}")

    @given(seed=st.integers(min_value=0, max_value=1_000_000),
           model=contention_models())
    @settings(max_examples=examples(50), deadline=None)
    def test_shared_model_strategies_agree_too(self, seed, model):
        platform, wls, _ = random_scenario(seed)
        ref = simulate(platform, wls, model, record_timeline=False)
        res = simulate_batch(platform, [wls], model).result(0)
        assert_equivalent(ref, res, f"seed={seed}")


class TestVectorizedSlowdown:
    @given(model=contention_models(),
           own=st.floats(0.0, 1.5), ext=st.floats(0.0, 1.5))
    @settings(max_examples=examples(200), deadline=None)
    def test_slowdown_array_matches_scalar(self, model, own, ext):
        arr = slowdown_array(model, np.array([own]), np.array([ext]))
        assert float(arr[0]) == pytest.approx(model.slowdown(own, ext),
                                              abs=1e-12)

    def test_unregistered_model_falls_back_elementwise(self):
        class Odd:
            def slowdown(self, own, external):
                return 1.0 + 0.25 * own * external

        own = np.array([0.2, 0.8, 1.1])
        ext = np.array([0.5, 0.0, 1.2])
        got = slowdown_array(Odd(), own, ext)
        want = [Odd().slowdown(o, e) for o, e in zip(own, ext)]
        assert got == pytest.approx(want, abs=1e-12)

    def test_wrapper_model_with_base_factor_attrs_uses_its_own_semantics(self):
        """A third-party wrapper exposing .base/.factor must NOT be treated
        as a ScaledContentionModel — the elementwise fallback has to call
        *its* slowdown, not guess a formula from attribute names."""
        class PowModel:
            def __init__(self, base, factor):
                self.base = base
                self.factor = factor

            def slowdown(self, own, external):
                return self.base.slowdown(own, external) ** self.factor

        m = PowModel(ProportionalShareModel(), 2.0)
        got = float(slowdown_array(m, np.array([0.9]), np.array([0.9]))[0])
        assert got == pytest.approx(m.slowdown(0.9, 0.9), abs=1e-12)

    def test_scaled_model_vectorized_path_matches_scalar(self):
        from repro.core.dynamic import ScaledContentionModel
        m = ScaledContentionModel(ProportionalShareModel(), 2.5)
        own = np.array([0.2, 0.9, 1.2])
        ext = np.array([0.9, 0.9, 0.3])
        got = slowdown_array(m, own, ext)
        want = [m.slowdown(o, e) for o, e in zip(own, ext)]
        assert got == pytest.approx(want, abs=1e-12)


class TestTargetedDifferential:
    """Deterministic corner cases the random generator may visit rarely."""

    def setup_method(self):
        self.plat = Platform(
            name="t", accelerators=(
                Accelerator("A", 1e12, 1e11, transition_in_ms=0.01,
                            transition_out_ms=0.02),
                Accelerator("B", 1e12, 1e11, transition_in_ms=0.03,
                            transition_out_ms=0.04)),
            transition_bw=1e11,
            domains={"EMC": ("A", "B")}, domain_bw={"EMC": 1e11})
        self.model = ProportionalShareModel(capacity=1.0, sensitivity=2.0)

    def _check(self, wls):
        ref = simulate(self.plat, wls, self.model, record_timeline=False)
        res = simulate_batch(self.plat, [wls], self.model).result(0)
        assert_equivalent(ref, res)

    def test_transition_delays(self):
        g = DNNGraph("n", (
            LayerGroup("a", {"A": 1.0, "B": 2.0}, {"A": 0.9, "B": 0.9},
                       out_bytes=5e7),
            LayerGroup("b", {"A": 2.0, "B": 1.0}, {"A": 0.9, "B": 0.9},
                       out_bytes=5e7),
            LayerGroup("c", {"A": 1.0, "B": 1.5}, {"A": 0.9, "B": 0.9})))
        other = DNNGraph("m", (
            LayerGroup("x", {"A": 4.0, "B": 4.0}, {"A": 0.8, "B": 0.8}),))
        self._check([Workload(g, ("A", "B", "A")),
                     Workload(other, ("B",))])

    def test_streaming_pipeline_with_arrivals(self):
        prod = DNNGraph("prod", (
            LayerGroup("p", {"A": 1.0, "B": 1.5}, {"A": 0.7, "B": 0.7}),))
        cons = DNNGraph("cons", (
            LayerGroup("c", {"A": 1.2, "B": 0.8}, {"A": 0.9, "B": 0.9}),))
        self._check([
            Workload(prod, ("A",), iterations=4, arrival_ms=0.5),
            Workload(cons, ("B",), iterations=4, depends_on=0,
                     arrival_ms=1.25),
        ])

    def test_queueing_same_accelerator_multi_iteration(self):
        g1 = DNNGraph("g1", (
            LayerGroup("a", {"A": 2.0, "B": 3.0}, {"A": 0.9, "B": 0.9}),))
        g2 = DNNGraph("g2", (
            LayerGroup("b", {"A": 1.0, "B": 1.0}, {"A": 0.9, "B": 0.9}),))
        self._check([Workload(g1, ("A",), iterations=3),
                     Workload(g2, ("A",), iterations=5, arrival_ms=0.25)])

    def test_per_domain_model_mapping(self):
        mapping = {"EMC": PiecewiseModel(
            (0.2, 0.6, 1.0), (0.2, 0.6, 1.0),
            ((1.0, 1.1, 1.3), (1.1, 1.4, 1.7), (1.3, 1.7, 2.2)))}
        g = DNNGraph("n", (
            LayerGroup("a", {"A": 2.0, "B": 2.0}, {"A": 0.8, "B": 0.8}),))
        h = DNNGraph("m", (
            LayerGroup("b", {"A": 3.0, "B": 3.0}, {"A": 0.7, "B": 0.7}),))
        wls = [Workload(g, ("A",)), Workload(h, ("B",))]
        ref = simulate(self.plat, wls, mapping, record_timeline=False)
        res = simulate_batch(self.plat, [wls], mapping).result(0)
        assert_equivalent(ref, res)

    def test_assignment_fast_path_matches_workload_path(self):
        g1 = DNNGraph("g1", (
            LayerGroup("a", {"A": 1.0, "B": 2.0}, {"A": 0.9, "B": 0.6},
                       out_bytes=1e8),
            LayerGroup("b", {"A": 2.0, "B": 1.0}, {"A": 0.5, "B": 0.8})))
        g2 = DNNGraph("g2", (
            LayerGroup("c", {"A": 1.5, "B": 1.5}, {"A": 0.7, "B": 0.7}),))
        combos = [(("A", "A"), ("B",)), (("A", "B"), ("A",)),
                  (("B", "B"), ("B",)), (("B", "A"), ("A",))]
        bt = simulate_assignments(self.plat, [g1, g2], combos, self.model,
                                  iterations=[2, 3], depends_on=[None, 0])
        for i, (a1, a2) in enumerate(combos):
            ref = simulate(self.plat, [
                Workload(g1, a1, iterations=2),
                Workload(g2, a2, iterations=3, depends_on=0)],
                self.model, record_timeline=False)
            assert_equivalent(ref, bt.result(i), f"cand={i}")

    def test_objective_vector_matches_scalar_objectives(self):
        g = DNNGraph("n", (
            LayerGroup("a", {"A": 1.0, "B": 2.0}, {"A": 0.9, "B": 0.9}),))
        h = DNNGraph("m", (
            LayerGroup("b", {"A": 2.0, "B": 1.0}, {"A": 0.9, "B": 0.9}),))
        combos = [(("A",), ("B",)), (("B",), ("A",)), (("A",), ("A",))]
        bt = simulate_assignments(self.plat, [g, h], combos, self.model)
        for kind in ("latency", "throughput", "sum_inverse"):
            objs = bt.objective(kind)
            for i, (a1, a2) in enumerate(combos):
                ref = simulate(self.plat,
                               [Workload(g, a1), Workload(h, a2)],
                               self.model, record_timeline=False)
                assert objs[i] == pytest.approx(ref.objective(kind),
                                                rel=1e-9)

    def test_validation_matches_scalar(self):
        g = DNNGraph("n", (
            LayerGroup("a", {"A": 1.0}, can_transition_after=False),
            LayerGroup("b", {"A": 1.0, "B": 1.0})))
        with pytest.raises(ValueError, match="illegal transition"):
            simulate_assignments(self.plat, [g], [(("A", "B"),)], self.model)
        with pytest.raises(ValueError):
            simulate_assignments(self.plat, [g], [(("A", "C"),)], self.model)

    def test_empty_batch(self):
        bt = simulate_batch(self.plat, [], self.model)
        assert len(bt) == 0
        assert bt.objective("latency").shape == (0,)


@needs_jax
class TestJaxDifferential:
    """Three-way parity: the XLA evaluator against scalar and batch.

    Covers the full random corpus (transition delays, ``depends_on``
    pipelines, ``arrival_ms`` offsets, multi-iteration workloads,
    per-domain model mappings) plus the assignment fast path and both
    precisions.
    """

    @given(seed=st.integers(min_value=0, max_value=10_000_000))
    @settings(max_examples=examples(60), deadline=None)
    def test_jax_matches_scalar_and_batch_on_random_scenarios(self, seed):
        platform, wls, model = random_scenario(seed)
        ref = simulate(platform, wls, model, record_timeline=False)
        res_b = simulate_batch(platform, [wls], model).result(0)
        res_j = simulate_jax.simulate_batch(platform, [wls], model).result(0)
        assert_equivalent(ref, res_j, f"seed={seed} jax-vs-scalar",
                          tol=JAX_TOL)
        assert_equivalent(res_b, res_j, f"seed={seed} jax-vs-batch",
                          tol=JAX_TOL)

    @given(seed=st.integers(min_value=0, max_value=1_000_000))
    @settings(max_examples=examples(15), deadline=None)
    def test_jax_population_members_are_independent(self, seed):
        rng = random.Random(seed)
        platform = random_platform(rng)
        model = random_model(rng, platform)
        batch = [random_workloads(rng, platform) for _ in range(6)]
        w = min(len(b) for b in batch)
        batch = [b[:w] for b in batch]
        bt = simulate_jax.simulate_batch(platform, batch, model)
        for i, wls in enumerate(batch):
            ref = simulate(platform, wls, model, record_timeline=False)
            assert_equivalent(ref, bt.result(i), f"seed={seed} cand={i}",
                              tol=JAX_TOL)

    def test_assignment_path_three_way(self):
        plat = Platform(
            name="t", accelerators=(
                Accelerator("A", 1e12, 1e11, transition_in_ms=0.01,
                            transition_out_ms=0.02),
                Accelerator("B", 1e12, 1e11, transition_in_ms=0.03,
                            transition_out_ms=0.04)),
            transition_bw=1e11,
            domains={"EMC": ("A", "B")}, domain_bw={"EMC": 1e11})
        model = ProportionalShareModel(capacity=1.0, sensitivity=2.0)
        g1 = DNNGraph("g1", (
            LayerGroup("a", {"A": 1.0, "B": 2.0}, {"A": 0.9, "B": 0.6},
                       out_bytes=1e8),
            LayerGroup("b", {"A": 2.0, "B": 1.0}, {"A": 0.5, "B": 0.8})))
        g2 = DNNGraph("g2", (
            LayerGroup("c", {"A": 1.5, "B": 1.5}, {"A": 0.7, "B": 0.7}),))
        combos = [(("A", "A"), ("B",)), (("A", "B"), ("A",)),
                  (("B", "B"), ("B",)), (("B", "A"), ("A",))]
        kw = dict(iterations=[2, 3], depends_on=[None, 0])
        bt_np = simulate_assignments(plat, [g1, g2], combos, model, **kw)
        bt_j = simulate_jax.simulate_assignments(plat, [g1, g2], combos,
                                                 model, **kw)
        for i, (a1, a2) in enumerate(combos):
            ref = simulate(plat, [
                Workload(g1, a1, iterations=2),
                Workload(g2, a2, iterations=3, depends_on=0)],
                model, record_timeline=False)
            assert_equivalent(ref, bt_j.result(i), f"cand={i}", tol=JAX_TOL)
            assert_equivalent(bt_np.result(i), bt_j.result(i), f"cand={i}",
                              tol=JAX_TOL)
        for kind in ("latency", "throughput", "sum_inverse"):
            assert bt_j.objective(kind) == pytest.approx(
                bt_np.objective(kind), rel=1e-6, abs=JAX_TOL)

    @given(spec=problem_specs())
    @settings(max_examples=examples(20), deadline=None)
    def test_spec_level_parity_numpy_vs_jax(self, spec):
        from repro.core.simulate_batch import simulate_spec as np_spec
        bn = np_spec(spec)
        bj = simulate_jax.simulate_spec(spec)
        assert bj.makespan == pytest.approx(bn.makespan, abs=JAX_TOL)
        assert bj.contention_ms == pytest.approx(bn.contention_ms,
                                                 abs=JAX_TOL)
        np.testing.assert_allclose(bj.finish_times, bn.finish_times,
                                   atol=JAX_TOL)

    def test_float32_precision_ranks_like_x64(self):
        """float32 is ranking-grade: makespans within ~1e-3 relative."""
        rng = random.Random(1234)
        platform = random_platform(rng)
        model = random_model(rng, platform)
        batch = [random_workloads(rng, platform) for _ in range(4)]
        w = min(len(b) for b in batch)
        batch = [b[:w] for b in batch]
        b64 = simulate_jax.simulate_batch(platform, batch, model)
        b32 = simulate_jax.simulate_batch(platform, batch, model,
                                          precision="float32")
        assert b32.makespan == pytest.approx(b64.makespan, rel=1e-3)

    def test_unlowerable_model_is_rejected_with_guidance(self):
        class Odd:
            def slowdown(self, own, external):
                return 1.0 + 0.25 * own * external

        platform, wls, _ = random_scenario(42)
        model = Odd()
        # NumPy path: works through the elementwise fallback.
        simulate_batch(platform, [wls], model)
        with pytest.raises(ValueError, match="register_surface_lowering"):
            simulate_jax.simulate_batch(platform, [wls], model)

    def test_scaled_model_three_way(self):
        from repro.core.dynamic import ScaledContentionModel
        platform, wls, base = random_scenario(77)
        if isinstance(base, dict):
            model = {k: ScaledContentionModel(v, 1.5)
                     for k, v in base.items()}
        else:
            model = ScaledContentionModel(base, 1.5)
        ref = simulate(platform, wls, model, record_timeline=False)
        assert_equivalent(ref,
                          simulate_batch(platform, [wls], model).result(0))
        assert_equivalent(
            ref, simulate_jax.simulate_batch(platform, [wls], model)
            .result(0), tol=JAX_TOL)


@needs_jax
class TestJaxGoldenPlans:
    """The jax evaluator must reproduce the pinned Table-6 fixtures."""

    def _fixtures(self):
        import pathlib
        return sorted((pathlib.Path(__file__).parent / "fixtures" /
                       "plans").glob("*.json"))

    def test_three_way_on_golden_fixtures(self):
        from repro.core import Plan
        paths = self._fixtures()
        assert len(paths) >= 3
        for path in paths:
            plan = Plan.load(path)
            req = plan.request
            wls = plan.solution.workloads
            ref = simulate(req.platform, wls, req.model,
                           record_timeline=False)
            bt_np = simulate_batch(req.platform, [wls], req.model)
            bt_j = simulate_jax.simulate_batch(req.platform, [wls],
                                               req.model)
            assert ref.makespan == pytest.approx(plan.result.makespan,
                                                 rel=1e-9), path.stem
            assert_equivalent(ref, bt_np.result(0), path.stem)
            assert_equivalent(ref, bt_j.result(0), path.stem, tol=JAX_TOL)
            assert bt_j.objective(req.objective)[0] == pytest.approx(
                plan.objective, rel=1e-6), path.stem

    def test_jax_evaluator_reproduces_fixture_solve(self):
        """End-to-end: solving with evaluator="jax" returns the golden
        schedule (the evaluator knob steers the search, never the answer)."""
        from repro.core import Plan, Scheduler
        path = self._fixtures()[0]
        golden = Plan.load(path)
        sched = Scheduler(golden.request.platform,
                          model=golden.request.model, evaluator="jax")
        plan = sched.resolve(golden.request)
        assert plan.evaluator == "jax"
        assert plan.assignments == golden.assignments
        assert plan.objective == pytest.approx(golden.objective, rel=1e-9)


@pytest.mark.slow
class TestDifferentialSweep:
    """Wider randomized sweep — scheduled CI job territory."""

    @given(seed=st.integers(min_value=10_000_001, max_value=20_000_000))
    @settings(max_examples=examples(500), deadline=None)
    def test_batch_matches_scalar_wide(self, seed):
        platform, wls, model = random_scenario(seed)
        ref = simulate(platform, wls, model, record_timeline=False)
        res = simulate_batch(platform, [wls], model).result(0)
        assert_equivalent(ref, res, f"seed={seed}")

    @needs_jax
    @given(seed=st.integers(min_value=20_000_001, max_value=30_000_000))
    @settings(max_examples=examples(150), deadline=None)
    def test_jax_matches_scalar_wide(self, seed):
        platform, wls, model = random_scenario(seed)
        ref = simulate(platform, wls, model, record_timeline=False)
        res = simulate_jax.simulate_batch(platform, [wls], model).result(0)
        assert_equivalent(ref, res, f"seed={seed}", tol=JAX_TOL)

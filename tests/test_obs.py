"""Unified observability layer: span tracer, metrics registry, timeline
export, logging hierarchy, and the canonical serving-metrics schemas.

Pins the load-bearing contracts of :mod:`repro.obs`:

* disabled tracing is a structural no-op (shared null context, no
  allocation per call);
* virtual-clock replays export **byte-identical** Perfetto JSON;
* exported traces are structurally valid Chrome trace-event documents;
* Prometheus text exposition matches a golden block exactly;
* all four serving providers (engine, multi-tenant gateway, fleet
  report/gateway, admission controller) conform to the schemas in
  :mod:`repro.obs.metrics` — key set *and* order.
"""
import io
import json
import logging
import threading

import pytest

from repro import obs
from repro.obs import (ADMISSION_SCHEMA, GATEWAY_SCHEMA, MetricsRegistry,
                       NULL_TRACER, TENANT_SCHEMA, Tracer, conform,
                       configure_logging, get_logger, get_tracer,
                       set_tracer)
from repro.obs.timeline import (ascii_gantt, plan_ascii, plan_chrome,
                                timeline_chrome, timeline_events)

from benchmarks.bench_obs import validate_chrome


@pytest.fixture(autouse=True)
def _restore_global_tracer():
    prev = set_tracer(None)
    yield
    set_tracer(prev)


def fake_clock(step_ms=1.0):
    """Deterministic monotonic clock: 0, step, 2*step, ..."""
    state = {"t": -step_ms}

    def clock():
        state["t"] += step_ms
        return state["t"]
    return clock


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

class TestSpans:
    def test_span_records_complete_event(self):
        tr = Tracer(clock=fake_clock())
        with tr.span("solve", "core", solver="bb") as sp:
            sp.set(objective=9.9)
        (ev,) = tr.events()
        assert ev["ph"] == "X" and ev["name"] == "solve"
        assert ev["cat"] == "core"
        assert ev["args"] == {"solver": "bb", "objective": 9.9}
        assert ev["ts"] == 0.0 and ev["dur"] == 1000.0  # µs, 1 ms clock

    def test_nested_spans_close_inner_first(self):
        tr = Tracer(clock=fake_clock())
        with tr.span("outer"):
            with tr.span("inner"):
                pass
        names = [e["name"] for e in tr.events()]
        assert names == ["inner", "outer"]
        inner, outer = tr.events()
        assert outer["ts"] <= inner["ts"]
        assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]

    def test_span_survives_exceptions(self):
        tr = Tracer(clock=fake_clock())
        with pytest.raises(ValueError):
            with tr.span("boom"):
                raise ValueError("x")
        assert [e["name"] for e in tr.events()] == ["boom"]

    def test_instant_with_virtual_timestamp_and_track(self):
        tr = Tracer(clock=fake_clock())
        tr.instant("fleet.reschedule", "dynamic", ts_ms=123.456,
                   track="fleet", plan="p13")
        (ev,) = tr.events()
        assert ev["ph"] == "i" and ev["s"] == "t"
        assert ev["ts"] == 123456.0
        assert ev["args"] == {"plan": "p13"}

    def test_decorator_late_binds_global_tracer(self):
        @obs.trace("decorated")
        def fn(x):
            return x + 1

        assert fn(1) == 2                      # null tracer: no events
        tr = Tracer(clock=fake_clock())
        set_tracer(tr)
        assert fn(2) == 3
        assert [e["name"] for e in tr.events()] == ["decorated"]

    def test_threads_get_own_tracks(self):
        tr = Tracer()
        n_threads, n_spans = 4, 50

        def work(i):
            for k in range(n_spans):
                with tr.span(f"t{i}.{k}"):
                    pass

        threads = [threading.Thread(target=work, args=(i,), name=f"w{i}")
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        events = tr.events()
        assert len(events) == n_threads * n_spans
        by_tid = {}
        for e in events:
            by_tid.setdefault(e["tid"], set()).add(e["name"].split(".")[0])
        # spans never leak onto another thread's track
        assert all(len(names) == 1 for names in by_tid.values())
        assert len(by_tid) == n_threads


class TestNullTracer:
    def test_default_tracer_is_null(self):
        assert get_tracer() is NULL_TRACER
        assert not get_tracer().enabled

    def test_span_returns_shared_context(self):
        # the no-op path must not allocate per call
        assert NULL_TRACER.span("a") is NULL_TRACER.span("b", "c", x=1)
        with NULL_TRACER.span("a") as sp:
            sp.set(anything="goes")        # swallowed, never raises

    def test_all_operations_are_noops(self):
        NULL_TRACER.instant("x", ts_ms=1.0, track="t")
        NULL_TRACER.complete("x", 0.0, 1.0)
        NULL_TRACER.add_events([{"ph": "X"}])
        NULL_TRACER.counter_sample("x", 0.0, {"v": 1})

    def test_decorator_returns_function_unchanged(self):
        def fn():
            return 42
        assert NULL_TRACER.trace(fn) is fn
        assert NULL_TRACER.trace("named")(fn) is fn


class TestChromeExport:
    def test_document_is_structurally_valid(self):
        tr = Tracer(clock=fake_clock())
        with tr.span("a"):
            tr.instant("evt", track="fleet")
        tr.complete("bulk", 0.0, 5.0, track="fleet/queue")
        tr.counter_sample("load", 1.0, {"q": 3})
        assert validate_chrome(tr.to_chrome()) == []

    def test_track_metadata_emitted_once_per_track(self):
        tr = Tracer(clock=fake_clock())
        tr.complete("s1", 0.0, 1.0, track="accA")
        tr.complete("s2", 1.0, 1.0, track="accA")
        doc = tr.to_chrome()
        meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
        assert [m["args"]["name"] for m in meta] == ["accA"]

    def test_same_inputs_same_bytes(self):
        def build():
            tr = Tracer(clock=fake_clock())
            with tr.span("solve", solver="bb"):
                tr.instant("hit", ts_ms=3.0, track="cache")
            return tr.to_json()
        assert build() == build()

    def test_track_id_shares_tid_registry(self):
        tr = Tracer(clock=fake_clock())
        with tr.span("main-span"):
            pass
        t1 = tr.track_id("plan0")
        t2 = tr.track_id("plan0/queue")
        assert len({tr.events()[0]["tid"], t1, t2}) == 3


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetricsRegistry:
    def test_idempotent_getters_and_kind_conflict(self):
        reg = MetricsRegistry()
        c = reg.counter("solves", "x")
        assert reg.counter("solves") is c
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("solves")

    def test_counter_gauge_histogram_roundtrip(self):
        reg = MetricsRegistry()
        reg.counter("hits").inc()
        reg.counter("hits").inc(2)
        reg.gauge("depth").set(7)
        h = reg.histogram("lat_ms", buckets=(1.0, 10.0))
        for v in (0.5, 5.0, 50.0):
            h.observe(v)
        snap = reg.snapshot()
        assert snap["repro_hits"] == {"kind": "counter", "value": 3.0}
        assert snap["repro_depth"]["value"] == 7.0
        assert snap["repro_lat_ms"]["count"] == 3
        assert snap["repro_lat_ms"]["buckets"] == {"1": 1, "10": 2}
        assert h.quantile(0.5) == 10.0

    def test_labeled_series(self):
        reg = MetricsRegistry()
        c = reg.counter("reqs")
        c.labels(tenant="a").inc(5)
        c.labels(tenant="b").inc()
        snap = reg.snapshot()["repro_reqs"]
        assert snap["series"] == {'{tenant="a"}': 5.0, '{tenant="b"}': 1.0}

    def test_json_snapshot_is_deterministic(self):
        reg = MetricsRegistry()
        reg.gauge("b").set(2)
        reg.gauge("a").set(1)
        assert json.loads(reg.to_json()) == reg.snapshot()
        assert reg.to_json() == reg.to_json()

    def test_prometheus_exposition_golden(self):
        reg = MetricsRegistry()
        reg.counter("cache_hits", "plan cache hits").labels(
            tier="mem").inc(4)
        reg.gauge("queue_depth", "queued requests").set(2)
        h = reg.histogram("step_ms", "decode step latency",
                          buckets=(1.0, 5.0))
        h.observe(0.3)
        h.observe(0.7)
        h.observe(3.0)
        h.observe(99.5)
        assert reg.to_prometheus() == (
            "# HELP repro_cache_hits plan cache hits\n"
            "# TYPE repro_cache_hits counter\n"
            'repro_cache_hits{tier="mem"} 4\n'
            "# HELP repro_queue_depth queued requests\n"
            "# TYPE repro_queue_depth gauge\n"
            "repro_queue_depth 2\n"
            "# HELP repro_step_ms decode step latency\n"
            "# TYPE repro_step_ms histogram\n"
            'repro_step_ms_bucket{le="1"} 2\n'
            'repro_step_ms_bucket{le="5"} 3\n'
            'repro_step_ms_bucket{le="+Inf"} 4\n'
            "repro_step_ms_sum 103.5\n"
            "repro_step_ms_count 4\n"
        )


class TestConform:
    def test_preserves_schema_order(self):
        shuffled = dict(reversed(list(
            {k: i for i, k in enumerate(TENANT_SCHEMA)}.items())))
        out = conform(TENANT_SCHEMA, shuffled)
        assert list(out) == list(TENANT_SCHEMA)

    def test_missing_key_fails_at_provider(self):
        values = {k: 0 for k in GATEWAY_SCHEMA}
        del values["reschedules"]
        with pytest.raises(KeyError, match="reschedules"):
            conform(GATEWAY_SCHEMA, values)

    def test_extra_keys_append_after_canonical_block(self):
        out = conform(GATEWAY_SCHEMA, {k: 0 for k in GATEWAY_SCHEMA},
                      tenants={})
        assert list(out)[-1] == "tenants"


# ---------------------------------------------------------------------------
# logging hierarchy
# ---------------------------------------------------------------------------

class TestLogging:
    def test_get_logger_pins_repro_hierarchy(self):
        assert get_logger("repro.core.scheduler").name == \
            "repro.core.scheduler"
        assert get_logger("benchmarks.bench_obs").name == \
            "repro.benchmarks.bench_obs"
        assert get_logger("__main__").name == "repro"
        assert get_logger("repro").name == "repro"

    def test_configure_logging_is_idempotent(self):
        root = configure_logging("info", stream=io.StringIO())
        configure_logging("debug", stream=io.StringIO())
        ours = [h for h in root.handlers
                if getattr(h, "_repro_obs", False)]
        assert len(ours) == 1
        assert root.level == logging.DEBUG

    def test_json_lines_are_parseable(self):
        buf = io.StringIO()
        configure_logging("info", json=True, stream=buf)
        get_logger("repro.core.plan").warning("degraded: %s", "corrupt")
        doc = json.loads(buf.getvalue().strip())
        assert doc["level"] == "warning"
        assert doc["logger"] == "repro.core.plan"
        assert doc["msg"] == "degraded: corrupt"


# ---------------------------------------------------------------------------
# schema conformance across every serving provider
# ---------------------------------------------------------------------------

class TestProviderConformance:
    def test_metric_keys_derive_from_tenant_schema(self):
        from repro.serve.engine import METRIC_KEYS
        assert METRIC_KEYS == tuple(TENANT_SCHEMA)

    def test_admission_controller_conforms(self):
        from repro.serve.fleet import SLO, AdmissionController
        ctl = AdmissionController(default_slo=SLO(p99_ms=100.0))
        m = ctl.metrics()
        assert tuple(m) == tuple(ADMISSION_SCHEMA)

    def test_schema_kinds_are_known(self):
        for schema in (TENANT_SCHEMA, GATEWAY_SCHEMA, ADMISSION_SCHEMA):
            for key, (kind, help_text) in schema.items():
                assert kind in ("counter", "gauge", "histogram"), key
                assert help_text, key


# ---------------------------------------------------------------------------
# fleet replay: byte-identical virtual-clock traces
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fleet_pool():
    from repro import configs
    from repro.core.accelerators import tpu_pod_split
    from repro.serve.fleet import build_pool
    from repro.serve.gateway import GatewayConfig, TenantSpec
    specs = [TenantSpec("stable", configs.get("stablelm-1.6b"),
                        max_slots=2, capacity=256, prompt_len=64,
                        max_new=16),
             TenantSpec("llama", configs.get("llama3.2-3b"),
                        max_slots=2, capacity=256, prompt_len=64,
                        max_new=16)]
    gcfg = GatewayConfig(max_transitions=1, body_groups=1)
    plats = [tpu_pod_split(1, 3, name="p13"),
             tpu_pod_split(2, 2, name="p22")]
    return build_pool(specs, plats, gcfg, slots=4, deadline_s=5.0)


def _traced_replay(pool, trace):
    from repro.serve.fleet import SLO, FleetConfig, FleetGateway
    tr = Tracer(clock=lambda: 0.0)
    prev = set_tracer(tr)
    try:
        cfg = FleetConfig(policy="slo", default_slo=SLO(p99_ms=1e9))
        gw = FleetGateway(pool, n_tenants=trace.n_tenants, cfg=cfg,
                          capacity_hint=len(trace))
        rep = gw.replay(trace)
        assert not rep.reschedules     # a solve would stamp wall time
        gw.export_trace(tracer=tr)
    finally:
        set_tracer(prev)
    return tr


class TestFleetTraceDeterminism:
    def test_identical_replays_export_identical_bytes(self, fleet_pool):
        from repro.serve.fleet import bursty_trace
        trace = bursty_trace(50.0, 300.0, 400, 20, seed=3)
        a = _traced_replay(fleet_pool, trace)
        b = _traced_replay(fleet_pool, trace)
        assert a.to_json() == b.to_json()
        assert len(a.events()) > 400       # replay span + request spans

    def test_exported_trace_is_valid_chrome(self, fleet_pool):
        from repro.serve.fleet import bursty_trace
        trace = bursty_trace(50.0, 300.0, 200, 10, seed=5)
        tr = _traced_replay(fleet_pool, trace)
        doc = tr.to_chrome()
        assert validate_chrome(doc) == []
        assert doc["otherData"]["clock"] == "virtual_ms"
        cats = {e.get("cat") for e in doc["traceEvents"]
                if e["ph"] == "X"}
        assert "service" in cats and "fleet" in cats

    def test_report_trace_events_standalone(self, fleet_pool):
        from repro.serve.fleet import SLO, FleetConfig, FleetGateway, \
            bursty_trace
        trace = bursty_trace(50.0, 300.0, 150, 10, seed=8)
        cfg = FleetConfig(policy="slo", default_slo=SLO(p99_ms=1e9))
        gw = FleetGateway(fleet_pool, n_tenants=trace.n_tenants, cfg=cfg,
                          capacity_hint=len(trace))
        rep = gw.replay(trace)
        events = rep.trace_events()
        # standalone mode brings its own thread_name metadata
        assert any(e["ph"] == "M" for e in events)
        svc = [e for e in events if e.get("cat") == "service"]
        assert len(svc) == rep.completed
        assert all(e["args"]["tenant"] is not None for e in svc)

    def test_truncation_is_logged_not_silent(self, fleet_pool, caplog):
        from repro.serve.fleet import SLO, FleetConfig, FleetGateway, \
            bursty_trace
        trace = bursty_trace(50.0, 300.0, 120, 10, seed=2)
        cfg = FleetConfig(policy="slo", default_slo=SLO(p99_ms=1e9))
        gw = FleetGateway(fleet_pool, n_tenants=trace.n_tenants, cfg=cfg,
                          capacity_hint=len(trace))
        rep = gw.replay(trace)
        # configure_logging pins propagate=False on the "repro" root;
        # let records reach caplog's handler for this one assertion.
        root = logging.getLogger("repro")
        prev_propagate = root.propagate
        root.propagate = True
        try:
            with caplog.at_level(logging.INFO, logger="repro.serve.fleet"):
                events = rep.trace_events(max_requests=50)
        finally:
            root.propagate = prev_propagate
        svc = [e for e in events if e.get("cat") == "service"]
        assert len(svc) == 50
        assert any("truncat" in r.message for r in caplog.records)


# ---------------------------------------------------------------------------
# timeline gantt
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def solved_plan():
    from repro.core import Scheduler
    sched = Scheduler("xavier-agx")
    return sched.solve(sched.graphs(["vgg19", "resnet101"]), "latency",
                       solver="bb", max_transitions=2)


class TestTimeline:
    def test_plan_chrome_is_valid_and_annotated(self, solved_plan):
        doc = plan_chrome(solved_plan)
        assert validate_chrome(doc) == []
        assert doc["otherData"]["solver"] == "bb"
        assert doc["otherData"]["makespan_ms"] == pytest.approx(
            solved_plan.objective, rel=1e-6)
        cats = {e["cat"] for e in doc["traceEvents"] if e["ph"] == "X"}
        assert "compute" in cats or "contention" in cats
        tracks = {e["args"]["name"] for e in doc["traceEvents"]
                  if e["ph"] == "M"}
        assert tracks <= {"GPU", "DLA", "CPU"} and len(tracks) >= 2

    def test_interval_events_carry_slowdown(self, solved_plan):
        from repro.obs.timeline import _plan_result
        res = _plan_result(solved_plan)
        events = timeline_events(res, ["vgg19", "resnet101"])
        xs = [e for e in events if e["ph"] == "X"
              and e["cat"] in ("compute", "contention")]
        assert len(xs) == len(res.timeline)
        for e in xs:
            assert e["args"]["slowdown"] >= 1.0 or \
                e["cat"] == "compute"
        assert all(e["cat"] == "contention"
                   for e in xs if e["args"]["slowdown"] > 1.000001)

    def test_ascii_gantt_rows_cover_accelerators(self, solved_plan):
        text = plan_ascii(solved_plan, width=40)
        lines = text.splitlines()
        assert lines[0].startswith("gantt 0..")
        rows = [ln for ln in lines if "|" in ln]
        assert len(rows) >= 2                   # GPU + DLA
        assert any("#" in r or "▒" in r for r in rows)

    def test_chrome_and_ascii_agree_on_makespan(self, solved_plan):
        from repro.obs.timeline import _plan_result
        res = _plan_result(solved_plan)
        doc = timeline_chrome(res)
        last_end = max(e["ts"] + e["dur"]
                       for e in doc["traceEvents"] if e["ph"] == "X")
        assert last_end == pytest.approx(res.makespan * 1e3, rel=1e-6)
        assert f"{res.makespan:.2f}" in ascii_gantt(res).splitlines()[0]


# ---------------------------------------------------------------------------
# instrumented scheduler surfaces
# ---------------------------------------------------------------------------

class TestSchedulerInstrumentation:
    def test_resolve_spans_tag_cache_hit_and_miss(self):
        from repro.core import Scheduler
        tr = Tracer()
        set_tracer(tr)
        sched = Scheduler("xavier-agx")
        req = sched.request(["vgg19", "resnet101"], solver="bb",
                            max_transitions=1)
        sched.resolve(req)
        sched.resolve(req)
        spans = [e for e in tr.events()
                 if e["name"] == "scheduler.resolve"]
        assert [s["args"]["cache"] for s in spans] == ["miss", "hit"]
        assert spans[0]["args"]["solve_s"] > 0
        solver_spans = [e for e in tr.events()
                        if e["name"].startswith("solver.")]
        assert len(solver_spans) == 1

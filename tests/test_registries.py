"""UnknownEntryError name-listing across every core registry.

PR 4 gave the evaluator registry typo-friendly failures: an unknown name
raises ``UnknownEntryError`` whose message lists the registered entries.
This pins the same contract for the solver, contention-model and baseline
registries, at both the registry layer and the user-facing surfaces
(``ScheduleRequest``, ``Scheduler``, plan deserialization).
"""
import pytest

from repro.core import Scheduler
from repro.core import registry
from repro.core.registry import UnknownEntryError


class TestSolverRegistry:
    def test_unknown_name_lists_registered(self):
        with pytest.raises(UnknownEntryError) as ei:
            registry.get_solver("simplex")
        msg = str(ei.value)
        assert "simplex" in msg
        for name in registry.solver_names():
            assert name in msg

    def test_request_fails_at_construction(self):
        sched = Scheduler("xavier-agx")
        with pytest.raises(UnknownEntryError, match="greedy"):
            sched.request(["vgg19"], solver="simplex")

    def test_anneal_is_registered_and_listed_in_errors(self):
        # PR 6: the device-resident annealer is a first-class registry
        # entry — unknown-solver errors must advertise it.
        assert "anneal" in registry.solver_names()
        with pytest.raises(UnknownEntryError, match="anneal"):
            registry.get_solver("simplex")

    def test_anneal_is_opt_in_never_auto(self):
        # greedy (priority 20) always succeeds, so the auto chain must
        # stop before the opt-in device search (priority 30).
        assert (registry.get_solver("greedy").priority
                < registry.get_solver("anneal").priority)

    def test_anneal_declares_its_knob_vocabulary(self):
        entry = registry.get_solver("anneal")
        for knob in ("population", "devices", "budget_ms", "fanout"):
            assert knob in entry.knobs

    def test_unknown_knob_lists_the_vocabulary(self):
        with pytest.raises(UnknownEntryError) as ei:
            registry.validate_solver_knobs("anneal", {"temperature": 3})
        msg = str(ei.value)
        assert "temperature" in msg
        for knob in registry.get_solver("anneal").knobs:
            assert knob in msg

    def test_knobs_on_knobless_solver_rejected(self):
        with pytest.raises(UnknownEntryError, match="none"):
            registry.validate_solver_knobs("bb", {"population": 512})

    def test_knobs_with_auto_name_solvers_that_accept_knobs(self):
        with pytest.raises(UnknownEntryError, match="anneal"):
            registry.validate_solver_knobs("auto", {"population": 512})


class TestEvaluatorRegistry:
    def test_unknown_name_lists_registered(self):
        with pytest.raises(UnknownEntryError) as ei:
            registry.get_evaluator("tensorrt")
        msg = str(ei.value)
        for name in registry.evaluator_names():
            assert name in msg

    def test_scheduler_ctor_fails(self):
        with pytest.raises(UnknownEntryError, match="scalar"):
            Scheduler("xavier-agx", evaluator="tensorrt")


class TestContentionModelRegistry:
    def test_unknown_kind_lists_registered(self):
        with pytest.raises(UnknownEntryError) as ei:
            registry.decode_model({"kind": "gaussian"})
        msg = str(ei.value)
        assert "gaussian" in msg
        for name in registry.contention_model_names():
            assert name in msg

    def test_is_a_key_error_with_readable_str(self):
        # UnknownEntryError subclasses KeyError (call sites catching
        # KeyError keep working) but str() is the message, not a repr.
        with pytest.raises(KeyError) as ei:
            registry.decode_model({"kind": "gaussian"})
        assert not str(ei.value).startswith("'")

    def test_known_kinds_still_decode(self):
        m = registry.decode_model(
            {"kind": "proportional", "capacity": 1.0, "sensitivity": 2.0})
        assert m.sensitivity == 2.0


class TestBaselineRegistry:
    def test_unknown_name_lists_registered(self):
        with pytest.raises(UnknownEntryError) as ei:
            registry.get_baseline("random-placement")
        msg = str(ei.value)
        assert "random-placement" in msg
        for name in registry.baseline_names():
            assert name in msg

    def test_scheduler_surface(self):
        sched = Scheduler("xavier-agx")
        with pytest.raises(UnknownEntryError):
            sched.evaluate_baseline("random-placement", ["vgg19"])

"""Optional-hypothesis shim + shared strategies for the property tests.

When ``hypothesis`` is installed the real ``given``/``settings``/``st`` are
re-exported unchanged and deterministic profiles are registered so property
tests are reproducible in CI:

* ``default`` — derandomized, no deadline (local + CI fast lane);
* ``ci``      — derandomized, no deadline, capped example count;
* ``thorough``— randomized, 5x examples (the scheduled slow CI job runs
  with ``HYPOTHESIS_PROFILE=thorough``).

When hypothesis is missing (the CPU container ships without it) the property
tests degrade to a deterministic grid of examples instead of erroring at
collection time: each fallback strategy carries a small fixed sample list
and ``given`` runs the test body over their (capped) cartesian product.
Far weaker than hypothesis — but it keeps every invariant exercised and the
tier-1 suite collectable everywhere.

This module also hosts the shared *model* strategies for the contention
metamorphic suite (:func:`proportional_models`, :func:`piecewise_models`,
:func:`contention_models`) — piecewise surfaces are generated with
monotone-non-decreasing tables, matching any physically meaningful PCCS
calibration — plus the seeded random-scenario generators shared by the
differential suites (:func:`random_platform` / :func:`random_workloads` /
:func:`random_scenario`) and a strategy emitting lowered
:class:`~repro.core.lowering.ProblemSpec` instances directly
(:func:`problem_specs`).
"""
from __future__ import annotations

import itertools
import os
import random as _random

from repro.core.accelerators import Accelerator, Platform
from repro.core.contention import PiecewiseModel, ProportionalShareModel
from repro.core.graph import DNNGraph, LayerGroup
from repro.core.simulate import Workload

_PROFILE = os.environ.get("HYPOTHESIS_PROFILE", "default")
#: per-profile multiplier applied by :func:`examples` — explicit
#: ``@settings(max_examples=...)`` takes precedence over the loaded
#: profile in hypothesis, so per-test example counts must scale through
#: this helper for the thorough/ci lanes to mean anything.
_EXAMPLE_SCALE = {"default": 1.0, "ci": 0.25, "thorough": 5.0,
                  "search": 0.25}


def examples(n: int) -> int:
    """Per-test example budget, scaled by the active profile (>= 1)."""
    return max(1, int(n * _EXAMPLE_SCALE.get(_PROFILE, 1.0)))


try:
    import hypothesis.strategies as st
    from hypothesis import HealthCheck, given, settings
    HAVE_HYPOTHESIS = True

    settings.register_profile(
        "default", deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile(
        "ci", deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile(
        "thorough", deadline=None, derandomize=False,
        suppress_health_check=[HealthCheck.too_slow])
    # device-search lane: derandomized with a hard example cap — every
    # example runs a jit-compiled annealer, so iterations stay bounded.
    settings.register_profile(
        "search", deadline=None, derandomize=True, max_examples=8,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(_PROFILE if _PROFILE in ("default", "ci",
                                                   "thorough", "search")
                          else "default")
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _St:
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            lo, hi = float(min_value), float(max_value)
            span = hi - lo
            return _Strategy([lo, lo + 0.1 * span, lo + 0.5 * span,
                              lo + 0.9 * span, hi])

        @staticmethod
        def integers(min_value=0, max_value=10, **_kw):
            lo, hi = int(min_value), int(max_value)
            span = hi - lo
            # endpoints plus a spread through the range, deduplicated while
            # preserving order so small ranges do not repeat values
            raw = [lo, lo + 1, lo + span // 4, lo + span // 2,
                   lo + (3 * span) // 4, hi - 1, hi]
            out, seen = [], set()
            for v in raw:
                v = max(lo, min(hi, v))
                if v not in seen:
                    seen.add(v)
                    out.append(v)
            return _Strategy(out)

        @staticmethod
        def booleans():
            return _Strategy([False, True])

        @staticmethod
        def sampled_from(elements):
            return _Strategy(list(elements))

        @staticmethod
        def just(value):
            return _Strategy([value])

        @staticmethod
        def one_of(*strategies):
            out = []
            for s in strategies:
                out.extend(s.samples)
            return _Strategy(out)

        @staticmethod
        def tuples(*strategies):
            # stagger each component cycle by its position so tuples are
            # not locked to the all-equal-index diagonal
            cycled = []
            for i, s in enumerate(strategies):
                c = itertools.cycle(s.samples)
                for _ in range(i):
                    next(c)
                cycled.append(c)
            n = max(len(s.samples) for s in strategies)
            return _Strategy([tuple(next(c) for c in cycled)
                              for _ in range(n)])

        @staticmethod
        def lists(strategy, min_size=0, max_size=10, **_kw):
            base = strategy.samples
            out = []
            for size in {max(min_size, 1), min(max_size, len(base)),
                         max(min_size, min(max_size, 3))}:
                if min_size <= size <= max_size:
                    pool = itertools.cycle(base)
                    out.append([next(pool) for _ in range(size)])
            return _Strategy(out or [base[:max_size]])

    st = _St()

    def given(**strategies):
        # the cartesian product of sample grids (capped) — multi-argument
        # properties must see off-diagonal combinations, not only cases
        # where every argument takes the same grid value
        names = list(strategies)

        def deco(fn):
            def run(*args):
                combos = itertools.islice(
                    itertools.product(
                        *(strategies[n].samples for n in names)), 64)
                for vals in combos:
                    fn(*args, **dict(zip(names, vals)))
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco


# ---------------------------------------------------------------------------
# shared contention-model strategies (metamorphic suite, differential suite)
# ---------------------------------------------------------------------------

def _monotone_piecewise(knot_lo: float, steps: tuple[float, ...],
                        base: float, row_incs: tuple[float, ...],
                        col_incs: tuple[float, ...]) -> PiecewiseModel:
    """Build a PiecewiseModel with strictly increasing knots and a table
    that is monotone non-decreasing along both axes."""
    knots = []
    x = knot_lo
    for s in steps:
        knots.append(round(x, 6))
        x += 0.05 + s
    n = len(knots)
    table = []
    for i in range(n):
        row = []
        for j in range(n):
            v = base
            for k in range(i + 1):
                v += row_incs[k % len(row_incs)]
            for k in range(j + 1):
                v += col_incs[k % len(col_incs)]
            row.append(round(max(1.0, v), 9))
        table.append(tuple(row))
    return PiecewiseModel(tuple(knots), tuple(knots), tuple(table))


if HAVE_HYPOTHESIS:
    def proportional_models():
        return st.builds(
            ProportionalShareModel,
            capacity=st.floats(0.5, 1.5),
            sensitivity=st.floats(0.25, 3.0))

    def piecewise_models():
        inc = st.tuples(st.floats(0.0, 0.4), st.floats(0.0, 0.4),
                        st.floats(0.0, 0.4))
        return st.builds(
            _monotone_piecewise,
            knot_lo=st.floats(0.05, 0.3),
            steps=st.tuples(st.floats(0.0, 0.3), st.floats(0.0, 0.3),
                            st.floats(0.0, 0.3)),
            base=st.floats(1.0, 1.3),
            row_incs=inc,
            col_incs=inc)

    def contention_models():
        return st.one_of(proportional_models(), piecewise_models())
else:
    def proportional_models():
        return _Strategy([
            ProportionalShareModel(),
            ProportionalShareModel(capacity=1.0, sensitivity=3.0),
            ProportionalShareModel(capacity=0.8, sensitivity=0.5),
            ProportionalShareModel(capacity=1.4, sensitivity=2.0),
        ])

    def piecewise_models():
        return _Strategy([
            _monotone_piecewise(0.1, (0.1, 0.2, 0.1), 1.0,
                                (0.1, 0.2, 0.05), (0.05, 0.1, 0.3)),
            _monotone_piecewise(0.2, (0.0, 0.3, 0.0), 1.2,
                                (0.0, 0.4, 0.0), (0.2, 0.0, 0.1)),
            _monotone_piecewise(0.05, (0.25, 0.05, 0.2), 1.1,
                                (0.3, 0.0, 0.2), (0.0, 0.0, 0.0)),
        ])

    def contention_models():
        return _Strategy(proportional_models().samples
                         + piecewise_models().samples)


# ---------------------------------------------------------------------------
# shared seeded scenario generators (differential suites, spec strategy)
# ---------------------------------------------------------------------------

def random_platform(rng: _random.Random) -> Platform:
    n_acc = rng.choice([2, 2, 3])
    names = [f"ACC{i}" for i in range(n_acc)]
    accs = tuple(
        Accelerator(a, peak_flops=1e12, mem_bw=1e11,
                    transition_in_ms=rng.choice([0.0, rng.uniform(0, 0.05)]),
                    transition_out_ms=rng.choice([0.0, rng.uniform(0, 0.05)]))
        for a in names)
    domains = {"EMC": tuple(names)}
    if n_acc == 3 and rng.random() < 0.5:
        # overlapping domains: ACC1 contends through both
        domains = {"EMC": tuple(names[:2]), "AUX": tuple(names[1:])}
    return Platform(
        name="rand", accelerators=accs,
        transition_bw=rng.uniform(5e10, 2e11),
        domains=domains,
        domain_bw={d: 1e11 for d in domains})


def random_model(rng: _random.Random, platform: Platform):
    def one():
        if rng.random() < 0.5:
            return ProportionalShareModel(
                capacity=rng.uniform(0.8, 1.2),
                sensitivity=rng.uniform(0.5, 3.0))
        knots = tuple(sorted(rng.uniform(0.05, 1.3) for _ in range(3)))
        if len(set(knots)) < 3:
            return ProportionalShareModel()
        row = [1.0 + rng.uniform(0, 0.3)]
        for _ in range(2):
            row.append(row[-1] + rng.uniform(0, 0.4))
        table = [tuple(row)]
        for _ in range(2):
            table.append(tuple(v + rng.uniform(0, 0.4) for v in table[-1]))
        return PiecewiseModel(knots, knots, tuple(table))

    if rng.random() < 0.25:           # per-domain mapping form
        return {d: one() for d in platform.domains}
    return one()


def random_workloads(rng: _random.Random, platform: Platform
                     ) -> list[Workload]:
    names = list(platform.names)
    n_wl = rng.randint(1, 3)
    wls = []
    for w in range(n_wl):
        n_groups = rng.randint(1, 4)
        groups, assignment = [], []
        for i in range(n_groups):
            groups.append(LayerGroup(
                name=f"g{i}",
                times={a: rng.uniform(0.1, 5.0) for a in names},
                mem_demand={a: (rng.uniform(0.0, 1.2)
                                if rng.random() < 0.8 else 0.0)
                            for a in names},
                out_bytes=rng.uniform(0.0, 2e8),
                can_transition_after=rng.random() < 0.8))
            if i == 0:
                assignment.append(rng.choice(names))
            elif groups[i - 1].can_transition_after:
                assignment.append(rng.choice(names))
            else:
                assignment.append(assignment[-1])
        dep = None
        if w > 0 and rng.random() < 0.4:
            dep = rng.randrange(w)
        wls.append(Workload(
            DNNGraph(f"net{w}", tuple(groups)), tuple(assignment),
            iterations=rng.randint(1, 3), depends_on=dep,
            arrival_ms=rng.choice([0.0, rng.uniform(0.0, 3.0)])))
    return wls


def random_scenario(seed: int):
    rng = _random.Random(seed)
    platform = random_platform(rng)
    return platform, random_workloads(rng, platform), random_model(
        rng, platform)


def spec_from_seed(seed: int):
    """One seeded scenario, lowered straight to a ProblemSpec (a small
    multi-candidate population over a shared platform/model)."""
    from repro.core.lowering import lower_workloads

    rng = _random.Random(seed)
    platform = random_platform(rng)
    model = random_model(rng, platform)
    n_cand = rng.randint(1, 4)
    batch = [random_workloads(rng, platform) for _ in range(n_cand)]
    w = min(len(b) for b in batch)
    return lower_workloads(platform, [b[:w] for b in batch], model)


def search_problem_from_seed(seed: int):
    """One seeded scenario shaped for the device-resident search: the
    platform/model plus graphs, iterations, dependency indices and
    arrivals (the same generator the differential suites draw from)."""
    rng = _random.Random(seed)
    platform = random_platform(rng)
    model = random_model(rng, platform)
    wls = random_workloads(rng, platform)
    return (platform, [w.graph for w in wls], model,
            [w.iterations for w in wls], [w.depends_on for w in wls],
            [w.arrival_ms for w in wls])


def trace_from_seed(seed: int):
    """One seeded arrival trace covering every generator kind — the shared
    scenario builder behind :func:`arrival_traces`."""
    from repro.serve.fleet.traffic import (bursty_trace, diurnal_trace,
                                           poisson_trace)

    rng = _random.Random(seed)
    kind = rng.choice(["poisson", "bursty", "diurnal"])
    n = rng.choice([16, 100, 400])
    tenants = rng.choice([1, 7, 50])
    if kind == "poisson":
        return poisson_trace(rng.choice([5.0, 200.0]), n, tenants,
                             seed=seed, skew=rng.choice([0.0, 1.0]))
    if kind == "bursty":
        return bursty_trace(rng.choice([10.0, 100.0]),
                            rng.choice([200.0, 2000.0]), n, tenants,
                            seed=seed, mean_calm_s=rng.choice([2.0, 20.0]),
                            mean_burst_s=rng.choice([0.5, 4.0]))
    return diurnal_trace(rng.choice([50.0, 500.0]), n, tenants, seed=seed,
                         day_s=rng.choice([3600.0, 86400.0]))


if HAVE_HYPOTHESIS:
    def problem_specs():
        """Strategy emitting lowered ProblemSpec instances directly."""
        return st.builds(spec_from_seed,
                         st.integers(min_value=0, max_value=10_000_000))

    def search_problems():
        """Strategy emitting (platform, graphs, model, iterations,
        depends_on, arrivals) tuples for the device-resident search."""
        return st.builds(search_problem_from_seed,
                         st.integers(min_value=0, max_value=10_000_000))

    def arrival_traces():
        """Strategy emitting seeded fleet ArrivalTrace instances across
        every generator kind (poisson / bursty / diurnal)."""
        return st.builds(trace_from_seed,
                         st.integers(min_value=0, max_value=10_000_000))
else:
    def problem_specs():
        return _Strategy([spec_from_seed(s) for s in (0, 1, 2, 3, 5, 8)])

    def search_problems():
        return _Strategy([search_problem_from_seed(s)
                          for s in (0, 1, 2, 3, 5, 8)])

    def arrival_traces():
        return _Strategy([trace_from_seed(s) for s in (0, 1, 2, 3, 5, 8)])

"""Optional-hypothesis shim for the property-based tests.

When ``hypothesis`` is installed the real ``given``/``settings``/``st`` are
re-exported unchanged.  When it is missing (the CPU container ships without
it) the property tests degrade to a deterministic grid of examples instead
of erroring at collection time: each fallback strategy carries a small fixed
sample list and ``given`` runs the test body over their (capped) cartesian
product.  Far weaker than hypothesis — but it keeps every invariant
exercised and the tier-1 suite collectable everywhere.
"""
from __future__ import annotations

import itertools

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _St:
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            lo, hi = float(min_value), float(max_value)
            span = hi - lo
            return _Strategy([lo, lo + 0.1 * span, lo + 0.5 * span,
                              lo + 0.9 * span, hi])

        @staticmethod
        def tuples(*strategies):
            # stagger each component cycle by its position so tuples are
            # not locked to the all-equal-index diagonal
            cycled = []
            for i, s in enumerate(strategies):
                c = itertools.cycle(s.samples)
                for _ in range(i):
                    next(c)
                cycled.append(c)
            n = max(len(s.samples) for s in strategies)
            return _Strategy([tuple(next(c) for c in cycled)
                              for _ in range(n)])

        @staticmethod
        def lists(strategy, min_size=0, max_size=10, **_kw):
            base = strategy.samples
            out = []
            for size in {max(min_size, 1), min(max_size, len(base)),
                         max(min_size, min(max_size, 3))}:
                if min_size <= size <= max_size:
                    pool = itertools.cycle(base)
                    out.append([next(pool) for _ in range(size)])
            return _Strategy(out or [base[:max_size]])

    st = _St()

    def given(**strategies):
        # the cartesian product of sample grids (capped) — multi-argument
        # properties must see off-diagonal combinations, not only cases
        # where every argument takes the same grid value
        names = list(strategies)

        def deco(fn):
            def run(*args):
                combos = itertools.islice(
                    itertools.product(
                        *(strategies[n].samples for n in names)), 64)
                for vals in combos:
                    fn(*args, **dict(zip(names, vals)))
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

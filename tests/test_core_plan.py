"""Scheduler/Plan API: request validation, serialization round-trip,
plan-cache semantics, solver registry dispatch and solver parity."""
import json

import pytest

from repro.core import (Plan, PlanCache, Scheduler, ScheduleRequest,
                        registry, solver_bb)
from repro.core.contention import ProportionalShareModel
from repro.core.dynamic import ScaledContentionModel, reschedule_plan
from repro.core.graph import DNNGraph, LayerGroup
from repro.core.scheduler import failed
from repro.core.solver_z3 import HAVE_Z3

DNNS = ["googlenet", "resnet18"]


def small_scheduler(**kw):
    return Scheduler("xavier-agx", **kw)


def small_request(sched, **kw):
    kw.setdefault("solver", "bb")
    kw.setdefault("max_transitions", 1)
    return sched.request(DNNS, "latency", **kw)


# ---------------------------------------------------------------------------
# ScheduleRequest
# ---------------------------------------------------------------------------

class TestScheduleRequest:
    def test_normalizes_and_hashes_stably(self):
        sched = small_scheduler()
        r1 = small_request(sched)
        r2 = small_request(sched, iterations=[1, 1], depends_on=[None, None])
        assert r1.iterations == (1, 1)
        assert r1.request_hash() == r2.request_hash()

    def test_different_problem_different_hash(self):
        sched = small_scheduler()
        assert (small_request(sched).request_hash()
                != small_request(sched, iterations=[2, 1]).request_hash())
        assert (small_request(sched).request_hash()
                != small_request(sched, deadline_s=1.0).request_hash())

    def test_rejects_bad_objective(self):
        with pytest.raises(ValueError, match="objective"):
            small_scheduler().request(DNNS, "qps")

    def test_rejects_unknown_solver_with_known_names(self):
        with pytest.raises(KeyError, match="bb"):
            small_scheduler().request(DNNS, solver="simplex")

    def test_rejects_mismatched_iterations(self):
        with pytest.raises(ValueError, match="iterations"):
            small_scheduler().request(DNNS, iterations=[1, 2, 3])

    def test_rejects_bad_dependency(self):
        with pytest.raises(ValueError, match="depends_on"):
            small_scheduler().request(DNNS, depends_on=[1, 1])

    def test_rejects_dependency_cycle(self):
        with pytest.raises(ValueError, match="cycle"):
            small_scheduler().request(DNNS, depends_on=[1, 0])


# ---------------------------------------------------------------------------
# solver knobs (per-entry pass-through vocabulary)
# ---------------------------------------------------------------------------

class TestSolverKnobs:
    def test_mapping_and_kwargs_forms_normalize_identically(self):
        sched = small_scheduler()
        r1 = sched.request(DNNS, solver="anneal", max_transitions=1,
                           solver_knobs={"devices": 2, "budget_ms": 50.0})
        r2 = sched.request(DNNS, solver="anneal", max_transitions=1,
                           budget_ms=50.0, devices=2)
        assert r1.solver_knobs == (("budget_ms", 50.0), ("devices", 2))
        assert r1.request_hash() == r2.request_hash()

    def test_knobs_change_the_request_hash(self):
        sched = small_scheduler()
        bare = sched.request(DNNS, solver="anneal", max_transitions=1)
        knobbed = sched.request(DNNS, solver="anneal", max_transitions=1,
                                population=512)
        assert bare.request_hash() != knobbed.request_hash()

    def test_knob_free_serialization_is_back_compat(self):
        # pre-knob artifacts hash without a solver_knobs key; knob-free
        # requests must keep emitting (and hashing) the same document.
        sched = small_scheduler()
        bare = small_request(sched)
        assert "solver_knobs" not in bare.to_dict()
        knobbed = sched.request(DNNS, solver="anneal", max_transitions=1,
                                population=512)
        assert knobbed.to_dict()["solver_knobs"] == {"population": 512}

    def test_round_trips_through_plan_artifact(self):
        sched = small_scheduler()
        req = sched.request(DNNS, solver="anneal", max_transitions=1,
                            population=256, steps=8, island=8)
        back = ScheduleRequest.from_dict(json.loads(
            json.dumps(req.to_dict())))
        assert back.solver_knobs == req.solver_knobs
        assert back.request_hash() == req.request_hash()

    def test_unknown_knob_lists_valid_names(self):
        with pytest.raises(registry.UnknownEntryError,
                           match="population"):
            small_scheduler().request(DNNS, solver="anneal",
                                      max_transitions=1, temperature=3)

    def test_knobs_with_auto_solver_refused(self):
        with pytest.raises(registry.UnknownEntryError, match="explicit"):
            small_scheduler().request(DNNS, population=512)

    def test_non_scalar_knob_value_rejected(self):
        with pytest.raises(ValueError, match="scalar"):
            small_scheduler().request(DNNS, solver="anneal",
                                      max_transitions=1,
                                      population=[512])

    @pytest.mark.skipif(not registry.get_solver("anneal").available(),
                        reason="jax not installed")
    def test_knobs_reach_the_solver_and_its_provenance(self):
        sched = small_scheduler()
        plan = sched.solve(DNNS, solver="anneal", max_transitions=1,
                           population=64, steps=8, island=8,
                           evaluator="batch")
        assert plan.solver_params["population"] == 64
        assert plan.solver_params["steps"] == 8
        assert plan.solver_params["island"] == 8


# ---------------------------------------------------------------------------
# Plan serialization
# ---------------------------------------------------------------------------

class TestPlanRoundTrip:
    def test_json_round_trip_equality(self):
        sched = small_scheduler()
        plan = sched.resolve(small_request(sched))
        blob = plan.to_json()
        back = Plan.from_json(blob)
        assert back.request_hash == plan.request_hash
        assert back.request.request_hash() == plan.request_hash
        assert back.assignments == plan.assignments
        assert back.objective == pytest.approx(plan.objective, rel=1e-12)
        assert back.solver == plan.solver
        assert back.platform_fingerprint == plan.platform_fingerprint
        # serialization is a fixed point: a reloaded plan re-serializes
        # byte-identically
        assert back.to_json() == blob

    def test_save_load(self, tmp_path):
        sched = small_scheduler()
        plan = sched.resolve(small_request(sched))
        path = plan.save(tmp_path / "plans" / "p.json")
        loaded = Plan.load(path)
        assert loaded.assignments == plan.assignments

    def test_tampered_artifact_rejected(self):
        sched = small_scheduler()
        plan = sched.resolve(small_request(sched))
        doc = json.loads(plan.to_json())
        doc["request"]["max_transitions"] = 2      # silent schedule drift
        with pytest.raises(ValueError, match="hash"):
            Plan.from_json(json.dumps(doc))

    def test_custom_model_solves_and_caches_but_refuses_json(self):
        class MyModel:
            def slowdown(self, own, external):
                return 1.0 + max(0.0, own + external - 1.0)

            def __repr__(self):               # deterministic fingerprint
                return "MyModel()"

        sched = Scheduler("xavier-agx", model=MyModel())
        p1 = sched.resolve(small_request(sched))
        p2 = sched.resolve(small_request(sched))
        assert p2 is p1 and sched.solves == 1     # hash + cache still work
        with pytest.raises(TypeError, match="codec"):
            Plan.from_json(p1.to_json())          # only round-trip refuses

    def test_per_domain_model_mapping_round_trips(self):
        sched = small_scheduler()
        mapping = {"EMC": ProportionalShareModel(1.0, 2.0)}
        plan = sched.resolve(small_request(sched, model=mapping))
        back = Plan.from_json(plan.to_json())
        assert back.request.model == mapping

    def test_scaled_model_round_trips(self):
        sched = small_scheduler()
        plan = reschedule_plan(sched, sched.graphs(DNNS), 2.5,
                               objective="latency", max_transitions=1,
                               budget_s=0.2)
        back = Plan.from_json(plan.to_json())
        model = back.request.model
        assert isinstance(model, ScaledContentionModel)
        assert model.factor == 2.5
        assert isinstance(model.base, ProportionalShareModel)


# ---------------------------------------------------------------------------
# solver provenance (PR 6)
# ---------------------------------------------------------------------------

class TestSolverProvenance:
    @pytest.mark.skipif(not registry.get_solver("anneal").available(),
                        reason="jax not installed")
    def test_anneal_plan_records_params_and_round_trips(self):
        sched = small_scheduler()
        plan = sched.solve(DNNS, solver="anneal", max_transitions=1,
                           evaluator="batch")
        assert plan.solver == "anneal"
        for key in ("seed", "steps", "population"):
            assert key in plan.solver_params
        assert plan.solver_params["seed"] == 0
        assert "solver=anneal seed=0" in plan.summary()
        back = Plan.from_json(plan.to_json())
        assert back.solver_params == plan.solver_params
        assert back.solution.params == plan.solution.params
        assert back.to_json() == plan.to_json()

    def test_exact_solver_params_empty(self):
        sched = small_scheduler()
        plan = sched.resolve(small_request(sched))
        assert plan.solver_params == {}
        assert "seed=" not in plan.summary()

    def test_from_dict_back_compat_pre_provenance_artifacts(self):
        # PR-5-era artifacts have neither plan-level solver_params nor
        # solution-level params: they must load with empty provenance.
        sched = small_scheduler()
        plan = sched.resolve(small_request(sched))
        doc = json.loads(plan.to_json())
        del doc["solver_params"]
        del doc["solution"]["params"]
        back = Plan.from_json(json.dumps(doc))
        assert back.solver_params == {}
        assert back.solution.params == {}
        assert back.assignments == plan.assignments


# ---------------------------------------------------------------------------
# PlanCache
# ---------------------------------------------------------------------------

class TestPlanCache:
    def test_hit_and_miss_semantics(self):
        sched = small_scheduler()
        p1 = sched.resolve(small_request(sched))
        assert sched.solves == 1 and sched.cache.misses == 1
        p2 = sched.resolve(small_request(sched))
        assert p2 is p1                       # content-addressed: O(1) hit
        assert sched.solves == 1 and sched.cache.hits == 1
        sched.resolve(small_request(sched, iterations=[2, 1]))
        assert sched.solves == 2              # different problem: miss

    def test_disk_cache_cold_hit(self, tmp_path):
        s1 = small_scheduler(cache=PlanCache(tmp_path))
        p1 = s1.resolve(small_request(s1))
        # a different process with the same cache root hits cold
        s2 = small_scheduler(cache=PlanCache(tmp_path))
        p2 = s2.resolve(small_request(s2))
        assert s2.solves == 0 and s2.cache.hits == 1
        assert p2.assignments == p1.assignments

    def test_corrupt_disk_artifact_degrades_to_miss(self, tmp_path):
        s1 = small_scheduler(cache=PlanCache(tmp_path))
        s1.resolve(small_request(s1))
        cache_file = next(tmp_path.glob("plan-*.json"))
        cache_file.write_text("{not json")
        s2 = small_scheduler(cache=PlanCache(tmp_path))
        plan = s2.resolve(small_request(s2))       # re-solves, no crash
        assert s2.solves == 1 and plan.result.makespan > 0

    def test_max_entries_evicts_fifo(self):
        sched = small_scheduler(cache=PlanCache(max_entries=1))
        sched.resolve(small_request(sched))
        sched.resolve(small_request(sched, iterations=[2, 1]))
        assert len(sched.cache) == 1
        sched.resolve(small_request(sched))        # evicted: re-solved
        assert sched.solves == 3

    def test_preloaded_artifact_skips_solver(self, tmp_path):
        s1 = small_scheduler()
        path = s1.resolve(small_request(s1)).save(tmp_path / "a.json")
        s2 = small_scheduler()
        s2.cache.add(Plan.load(path))
        plan = s2.resolve(small_request(s2))
        assert s2.solves == 0 and s2.cache.hits == 1
        assert plan.solver in registry.solver_names()


# ---------------------------------------------------------------------------
# solver registry
# ---------------------------------------------------------------------------

class TestSolverRegistry:
    def test_builtins_registered_in_priority_order(self):
        names = registry.solver_names()
        assert set(("z3", "bb", "greedy")) <= set(names)
        assert names.index("z3") < names.index("bb") < names.index("greedy")

    def test_unknown_solver_lists_known_names(self):
        with pytest.raises(KeyError, match="greedy"):
            registry.get_solver("simplex")

    def test_auto_degrades_past_refusing_solver(self, monkeypatch):
        def too_large(*a, **k):
            raise ValueError("search space too large")
        entries = dict(registry._SOLVERS)
        for name in ("z3", "bb"):
            import dataclasses
            monkeypatch.setitem(registry._SOLVERS, name,
                                dataclasses.replace(entries[name],
                                                    fn=too_large))
        sched = small_scheduler()
        plan = sched.resolve(small_request(sched, solver="auto"))
        assert plan.solver == "greedy"
        assert not plan.optimal

    def test_bb_z3_parity_on_small_problem(self):
        sched = small_scheduler()
        bb_plan = sched.resolve(small_request(sched, solver="bb"))
        if not HAVE_Z3:
            pytest.skip("z3 unavailable: parity half skipped")
        z3_plan = sched.resolve(small_request(sched, solver="z3"))
        assert z3_plan.objective == pytest.approx(bb_plan.objective,
                                                  rel=1e-9)

    def test_greedy_never_worse_than_best_baseline(self):
        sched = small_scheduler()
        graphs = sched.graphs(DNNS)
        best = min(
            sched.evaluate_baseline(n, graphs)[1].objective("latency")
            for n in registry.baseline_names())
        plan = sched.resolve(small_request(sched, solver="greedy"))
        assert plan.objective <= best + 1e-9
        for wl, g in zip(plan.solution.workloads, graphs):
            assert len(wl.assignment) == len(g)
        # and the exact solver bounds greedy from below
        exact = sched.resolve(small_request(sched, solver="bb"))
        assert plan.objective >= exact.objective - 1e-9


# ---------------------------------------------------------------------------
# compare(): structured error rows (infeasible != crashed)
# ---------------------------------------------------------------------------

class TestCompareErrorRows:
    def test_infeasible_baseline_is_structured_not_none(self):
        # gpu-only + dla-only graphs: fastest_only has no common accelerator
        g1 = DNNGraph("gpu-only", (LayerGroup("a", {"GPU": 1.0},
                                              {"GPU": 0.5}),))
        g2 = DNNGraph("dla-only", (LayerGroup("b", {"DLA": 1.0},
                                              {"DLA": 0.5}),))
        sched = small_scheduler()
        rows = sched.compare([g1, g2], "latency", max_transitions=1)
        row = rows["fastest_only"]
        assert failed(row)
        assert row["error"]["type"] == "ValueError"
        assert "accelerator" in row["error"]["message"]
        assert not failed(rows["naive_concurrent"])
        assert not failed(rows["haxconn"])
        assert rows["haxconn"].solution.result.makespan > 0

    def test_deprecated_api_compare_keeps_solution_shape(self):
        from repro.core import api
        with pytest.deprecated_call():
            rows = api.compare(DNNS, platform="xavier-agx",
                               deadline_s=5.0)
        assert isinstance(rows["haxconn"], solver_bb.Solution)
        for name in registry.baseline_names():
            assert not failed(rows[name])

    def test_simulate_time_failure_is_structured_not_fatal(self):
        # a model that crashes inside the simulator must degrade to
        # per-row error dicts, not take down the whole compare() sweep
        class Exploding:
            def slowdown(self, own, external):
                raise RuntimeError("boom at simulate time")

            def __repr__(self):
                return "Exploding()"

        sched = Scheduler("xavier-agx", model=Exploding())
        rows = sched.compare(DNNS, "latency", max_transitions=1,
                             solver="greedy")
        # the sweep survives and every baseline has a row: contention-free
        # ones (fastest_only never calls slowdown) succeed, concurrent ones
        # fail as structured RuntimeError rows, not an exception
        assert set(registry.baseline_names()) <= set(rows)
        errs = [rows[n] for n in registry.baseline_names()
                if failed(rows[n])]
        assert errs, "expected at least one simulate-time failure row"
        for row in errs:
            assert row["error"]["type"] == "RuntimeError"
            assert "boom" in row["error"]["message"]

    def test_pre_evaluator_solver_signature_still_dispatches(self):
        # third-party solvers registered against the old signature (no
        # evaluator kwarg) must keep working through Scheduler.resolve
        def legacy(platform, graphs, model, *, objective, max_transitions,
                   iterations, depends_on, deadline_s):
            from repro.core import solver_greedy
            return solver_greedy.solve(
                platform, graphs, model, objective=objective,
                max_transitions=max_transitions, iterations=iterations,
                depends_on=depends_on, evaluator="scalar")

        registry.register_solver("legacy-sig", priority=99)(legacy)
        try:
            sched = small_scheduler()
            plan = sched.resolve(small_request(sched, solver="legacy-sig"))
            assert plan.solver == "legacy-sig"
            assert plan.result.makespan > 0
        finally:
            registry._SOLVERS.pop("legacy-sig")

    def test_registered_baseline_feeds_compare_and_greedy(self):
        from repro.core.baselines import fastest_only
        registry.register_baseline("everything-fastest", fastest_only)
        try:
            sched = small_scheduler()
            rows = sched.compare(DNNS, "latency", max_transitions=1)
            assert "everything-fastest" in rows
            # greedy's incumbent scan sees registry entries too
            plan = sched.resolve(small_request(sched, solver="greedy"))
            base = sched.evaluate_baseline(
                "everything-fastest", DNNS)[1].objective("latency")
            assert plan.objective <= base + 1e-9
        finally:
            registry._BASELINES.pop("everything-fastest")


# ---------------------------------------------------------------------------
# plan-cache hardening: LRU semantics, truncation tolerance, sharded store
# ---------------------------------------------------------------------------

class TestPlanCacheHardening:
    def _requests(self, sched, n):
        """n distinct problems (different iteration counts)."""
        return [small_request(sched, iterations=[i + 1, 1])
                for i in range(n)]

    def test_hit_refreshes_lru_recency(self):
        sched = small_scheduler(cache=PlanCache(max_entries=2))
        r1, r2, r3 = self._requests(sched, 3)
        sched.resolve(r1)
        sched.resolve(r2)
        sched.resolve(r1)                     # refresh: r2 is now oldest
        sched.resolve(r3)                     # evicts r2, not r1
        solves = sched.solves
        sched.resolve(r1)                     # still cached
        assert sched.solves == solves
        sched.resolve(r2)                     # evicted: re-solved
        assert sched.solves == solves + 1

    def test_truncated_disk_artifact_degrades_to_miss(self, tmp_path):
        s1 = small_scheduler(cache=PlanCache(tmp_path))
        s1.resolve(small_request(s1))
        cache_file = next(tmp_path.glob("plan-*.json"))
        blob = cache_file.read_text()
        cache_file.write_text(blob[:len(blob) // 2])   # writer died mid-save
        s2 = small_scheduler(cache=PlanCache(tmp_path))
        plan = s2.resolve(small_request(s2))           # re-solves, no crash
        assert s2.solves == 1 and plan.result.makespan > 0

    def test_wrong_hash_disk_artifact_degrades_to_miss(self, tmp_path):
        """A decodable artifact stored under the wrong name is ignored."""
        s1 = small_scheduler(cache=PlanCache(tmp_path))
        s1.resolve(small_request(s1))
        src = next(tmp_path.glob("plan-*.json"))
        other = small_request(s1, iterations=[5, 1])
        src.rename(tmp_path / f"plan-{other.request_hash()[:16]}.json")
        s2 = small_scheduler(cache=PlanCache(tmp_path))
        s2.resolve(other)
        assert s2.solves == 1                          # mismatch -> miss


class TestShardedPlanCache:
    def test_layout_and_cross_instance_cold_hit(self, tmp_path):
        from repro.core import ShardedPlanCache
        s1 = small_scheduler(cache=ShardedPlanCache(tmp_path))
        p1 = s1.resolve(small_request(s1))
        path = s1.cache.path_for(p1.request_hash)
        assert path.exists()
        assert path.parent.name == p1.request_hash[:2]   # hash-prefix shard
        # a fresh scheduler over the same root boots without solving
        s2 = small_scheduler(cache=ShardedPlanCache(tmp_path))
        p2 = s2.resolve(small_request(s2))
        assert s2.solves == 0 and s2.cache.hits == 1
        assert p2.assignments == p1.assignments

    def test_disk_eviction_bounds_every_shard(self, tmp_path):
        from repro.core import ShardedPlanCache
        cache = ShardedPlanCache(tmp_path, shard_chars=1,
                                 max_disk_entries=16)    # budget 1/shard
        sched = small_scheduler(cache=cache)
        for i in range(4):
            sched.resolve(small_request(sched, iterations=[i + 1, 1]))
        budget = 1
        for shard in tmp_path.iterdir():
            assert len(list(shard.glob("plan-*.json"))) <= budget
        assert cache.disk_entries() <= 4

    def test_corrupt_shard_entry_degrades_to_miss(self, tmp_path):
        from repro.core import ShardedPlanCache
        s1 = small_scheduler(cache=ShardedPlanCache(tmp_path))
        p1 = s1.resolve(small_request(s1))
        s1.cache.path_for(p1.request_hash).write_text("{truncated")
        s2 = small_scheduler(cache=ShardedPlanCache(tmp_path))
        s2.resolve(small_request(s2))
        assert s2.solves == 1                  # corrupt entry re-solved

    def test_rejects_bad_shard_chars(self, tmp_path):
        from repro.core import ShardedPlanCache
        with pytest.raises(ValueError, match="shard_chars"):
            ShardedPlanCache(tmp_path, shard_chars=0)

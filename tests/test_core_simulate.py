"""Unit tests for the exact contention-interval timeline simulator."""
import pytest

from repro.core.accelerators import Accelerator, Platform
from repro.core.contention import ProportionalShareModel
from repro.core.graph import DNNGraph, LayerGroup
from repro.core.simulate import Workload, simulate, validate_assignment


def make_platform(epsilon=0.0, trans_bw=100e9):
    return Platform(
        name="test",
        accelerators=(
            Accelerator("A", peak_flops=1e12, mem_bw=100e9),
            Accelerator("B", peak_flops=1e12, mem_bw=100e9),
        ),
        transition_bw=trans_bw,
        domains={"EMC": ("A", "B")},
        domain_bw={"EMC": 100e9},
        epsilon_ms=epsilon,
    )


def g(name, times, demand=None, out_bytes=0.0, legal=True):
    return LayerGroup(name=name, times=times, mem_demand=demand or {},
                      out_bytes=out_bytes, can_transition_after=legal)


MODEL = ProportionalShareModel(capacity=1.0, sensitivity=1.0)


class TestSingleWorkload:
    def test_standalone_no_contention(self):
        plat = make_platform()
        graph = DNNGraph("net", (g("l0", {"A": 2.0, "B": 3.0}),
                                 g("l1", {"A": 1.0, "B": 4.0})))
        res = simulate(plat, [Workload(graph, ("A", "A"))], MODEL)
        assert res.makespan == pytest.approx(3.0)
        assert res.contention_ms == pytest.approx(0.0)

    def test_transition_cost_added(self):
        plat = make_platform()
        graph = DNNGraph("net", (
            g("l0", {"A": 2.0, "B": 3.0}, out_bytes=100e9 * 1e-3),  # 1ms move
            g("l1", {"A": 1.0, "B": 4.0}),
        ))
        res = simulate(plat, [Workload(graph, ("A", "B"))], MODEL)
        assert res.makespan == pytest.approx(2.0 + 1.0 + 4.0)

    def test_iterations_back_to_back(self):
        plat = make_platform()
        graph = DNNGraph("net", (g("l0", {"A": 2.0}),))
        res = simulate(plat, [Workload(graph, ("A",), iterations=5)], MODEL)
        assert res.makespan == pytest.approx(10.0)
        assert res.iteration_latencies[0] == pytest.approx([2.0] * 5)

    def test_illegal_transition_rejected(self):
        plat = make_platform()
        graph = DNNGraph("net", (g("l0", {"A": 1, "B": 1}, legal=False),
                                 g("l1", {"A": 1, "B": 1})))
        with pytest.raises(ValueError, match="illegal transition"):
            validate_assignment(plat, Workload(graph, ("A", "B")))


class TestQueueing:
    def test_same_accelerator_serializes(self):
        plat = make_platform()
        n1 = DNNGraph("n1", (g("x", {"A": 2.0}),))
        n2 = DNNGraph("n2", (g("y", {"A": 3.0}),))
        res = simulate(plat, [Workload(n1, ("A",)), Workload(n2, ("A",))],
                       MODEL)
        assert res.makespan == pytest.approx(5.0)
        # FIFO by index: n1 first
        assert res.finish_times == pytest.approx([2.0, 5.0])

    def test_dependency_pipeline(self):
        plat = make_platform()
        n1 = DNNGraph("n1", (g("x", {"A": 2.0}),))
        n2 = DNNGraph("n2", (g("y", {"B": 3.0}),))
        res = simulate(plat, [
            Workload(n1, ("A",), iterations=2),
            Workload(n2, ("B",), iterations=2, depends_on=0),
        ], MODEL)
        # n2 iter0 starts at 2 (after n1 iter0), iter1 starts at max(4, 5)=5
        assert res.finish_times[1] == pytest.approx(8.0)


class TestContention:
    def test_no_contention_below_capacity(self):
        plat = make_platform()
        n1 = DNNGraph("n1", (g("x", {"A": 4.0}, {"A": 0.4}),))
        n2 = DNNGraph("n2", (g("y", {"B": 4.0}, {"B": 0.5}),))
        res = simulate(plat, [Workload(n1, ("A",)), Workload(n2, ("B",))],
                       MODEL)
        assert res.makespan == pytest.approx(4.0)
        assert res.contention_ms == pytest.approx(0.0)

    def test_symmetric_oversubscription(self):
        # both request 0.8 -> total 1.6 -> slowdown 1 + 0.8*0.6 = 1.48
        plat = make_platform()
        n1 = DNNGraph("n1", (g("x", {"A": 4.0}, {"A": 0.8}),))
        n2 = DNNGraph("n2", (g("y", {"B": 4.0}, {"B": 0.8}),))
        res = simulate(plat, [Workload(n1, ("A",)), Workload(n2, ("B",))],
                       MODEL)
        assert res.makespan == pytest.approx(4.0 * 1.48, rel=1e-6)

    def test_asymmetric_tail_runs_clean(self):
        # n1 (2ms @0.8) overlaps n2 (8ms @0.8): n1 dilates to 2*1.48;
        # n2 dilated only while n1 active, then clean.
        plat = make_platform()
        n1 = DNNGraph("n1", (g("x", {"A": 2.0}, {"A": 0.8}),))
        n2 = DNNGraph("n2", (g("y", {"B": 8.0}, {"B": 0.8}),))
        res = simulate(plat, [Workload(n1, ("A",)), Workload(n2, ("B",))],
                       MODEL)
        t1 = 2.0 * 1.48
        # during [0, t1] n2 progressed t1/1.48 = 2.0 standalone-ms
        expected = t1 + (8.0 - 2.0)
        assert res.finish_times[0] == pytest.approx(t1)
        assert res.makespan == pytest.approx(expected, rel=1e-9)

    def test_contention_interval_accounting(self):
        plat = make_platform()
        n1 = DNNGraph("n1", (g("x", {"A": 2.0}, {"A": 0.8}),))
        n2 = DNNGraph("n2", (g("y", {"B": 8.0}, {"B": 0.8}),))
        res = simulate(plat, [Workload(n1, ("A",)), Workload(n2, ("B",))],
                       MODEL)
        # contention_ms = Σ (1 - 1/s)·len over intervals, both slowed in
        # [0, 2.96]: 2 * 2.96 * (1 - 1/1.48)
        assert res.contention_ms == pytest.approx(2 * 2.96 * (1 - 1 / 1.48),
                                                  rel=1e-6)

    def test_timeline_covers_execution(self):
        plat = make_platform()
        n1 = DNNGraph("n1", (g("x", {"A": 2.0}, {"A": 0.6}),
                             g("z", {"A": 1.0, "B": 1.0}, {"A": 0.5, "B": 0.5})))
        n2 = DNNGraph("n2", (g("y", {"B": 3.0}, {"B": 0.7}),))
        res = simulate(plat, [Workload(n1, ("A", "B")), Workload(n2, ("B",))],
                       MODEL)
        for iv in res.timeline:
            assert iv.end >= iv.start
            assert iv.slowdown >= 1.0
        # per-workload executed standalone-time equals graph times
        exec0 = sum((iv.end - iv.start) / iv.slowdown
                    for iv in res.timeline if iv.workload == 0)
        assert exec0 == pytest.approx(3.0, rel=1e-9)

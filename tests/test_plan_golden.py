"""Golden regression fixtures: three Table-6 scenarios, pinned forever.

Each fixture under ``tests/fixtures/plans/`` serializes a full scheduling
problem (graphs, platform, contention model — one experiment per §5.2
scenario type) together with the schedule the exact solver produced for it.
Re-solving the *deserialized* request on today's code must reproduce the
stored objective and assignments exactly: any solver or simulator refactor
that silently changes schedule quality fails here first.

Intentional behaviour changes regenerate the fixtures with
``PYTHONPATH=src python tests/fixtures/plans/regenerate.py``.
"""
import pathlib

import pytest

from repro.core import Plan, Scheduler

FIXTURES = sorted(
    (pathlib.Path(__file__).parent / "fixtures" / "plans").glob("*.json"))


def fixture_id(path: pathlib.Path) -> str:
    return path.stem


@pytest.mark.parametrize("path", FIXTURES, ids=fixture_id)
class TestGoldenPlans:
    def test_fixture_loads_and_verifies(self, path):
        plan = Plan.load(path)                 # hash tamper check included
        assert plan.solver == "bb"
        assert plan.optimal
        assert plan.result.makespan > 0

    def test_resolve_reproduces_fixture(self, path):
        golden = Plan.load(path)
        sched = Scheduler(golden.request.platform,
                          model=golden.request.model)
        plan = sched.resolve(golden.request)
        assert sched.solves == 1               # actually re-solved, no cache
        assert plan.assignments == golden.assignments
        assert plan.objective == pytest.approx(golden.objective, rel=1e-9)
        assert plan.optimal == golden.optimal
        assert plan.result.makespan == pytest.approx(
            golden.result.makespan, rel=1e-9)
        assert plan.result.throughput_fps == pytest.approx(
            golden.result.throughput_fps, rel=1e-9)

    def test_scalar_evaluator_reproduces_fixture_too(self, path):
        """The evaluator knob may steer the search, never the answer."""
        golden = Plan.load(path)
        sched = Scheduler(golden.request.platform,
                          model=golden.request.model, evaluator="scalar")
        plan = sched.resolve(golden.request)
        assert plan.assignments == golden.assignments
        assert plan.objective == pytest.approx(golden.objective, rel=1e-9)


def test_fixtures_present():
    # one golden plan per Table-6 scenario type (§5.2: 2, 3, 4)
    names = [p.stem for p in FIXTURES]
    for scenario in ("scenario2", "scenario3", "scenario4"):
        assert any(n.startswith(scenario) for n in names), names

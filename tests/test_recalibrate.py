"""Closed-loop online recalibration: §4.4 monitor hardening, streaming
PCCS re-fits, versioned bundle lineage, and the duty-cycle throttle axis.

Unit layers (monitor, quantizer, window, throttle state machine, token
bucket, bundle freeze + lineage) plus one drift-injected fleet smoke
exercising the whole loop: telemetry → re-fit → publish → adopt →
throttle.  The full-scale convergence/SLO gates live in
``benchmarks/bench_recalibrate.py``.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro import configs
from repro.core.accelerators import tpu_pod_split, xavier_agx
from repro.core.contention import PiecewiseModel, ProportionalShareModel
from repro.core.dynamic import (MAX_SEVERITY, SlowdownMonitor,
                                quantize_severity)
from repro.core.profiles import get_graph
from repro.profiling import (ProfileBundle, StreamingRecalibrator,
                             verify_lineage)
from repro.profiling.calibrate import fit_piecewise
from repro.profiling.online import SampleWindow
from repro.serve.fleet import (SLO, AdmissionController, FleetConfig,
                               FleetGateway, TenantThrottle, build_pool,
                               poisson_trace)
from repro.serve.fleet.loop import DONE, THROTTLED
from repro.serve.gateway import GatewayConfig, TenantSpec


# ---------------------------------------------------------------------------
# §4.4 monitor hardening (regressions)
# ---------------------------------------------------------------------------

class TestMonitorPoisoning:
    def _hot(self, **kw):
        """A monitor past warmup, mid-deviation."""
        m = SlowdownMonitor(threshold=1.5, patience=3, cooldown=4,
                            warmup=0, **kw)
        for _ in range(3):                       # EWMA 1.5→1.75→1.875:
            m.observe(2.0, 1.0)                  # two strikes on the board
        assert m.strikes == 2
        return m

    @pytest.mark.parametrize("observed,predicted", [
        (float("nan"), 1.0), (1.0, float("nan")),
        (float("inf"), 1.0), (1.0, float("inf")),
        (float("-inf"), 1.0), (-1.0, 1.0), (1.0, 0.0), (1.0, -2.0),
    ])
    def test_bad_sample_is_ignored(self, observed, predicted):
        m = self._hot()
        ratio = m.ratio
        assert m.observe(observed, predicted) is False
        assert m.ratio == ratio                 # EWMA untouched

    def test_monitor_survives_poisoned_stream(self):
        # the original bug: one NaN folded into the EWMA made every later
        # `ratio > threshold` comparison False — monitor silently dead.
        m = self._hot()
        m.observe(float("nan"), 1.0)
        assert m.observe(2.0, 1.0) is True       # third strike still fires
        assert math.isfinite(m.ratio)

    def test_clean_stream_still_fires(self):
        m = SlowdownMonitor(threshold=1.5, patience=3, cooldown=4, warmup=0)
        fired = [m.observe(2.0, 1.0) for _ in range(5)]
        # EWMA crosses the threshold on observation 2; patience=3 strikes
        # later the monitor fires exactly once, then holds off (cooldown).
        assert fired == [False, False, False, True, False]


class TestQuantizeSeverity:
    def test_snaps_to_sixteenths(self):
        assert quantize_severity(1.3) == pytest.approx(1.3125)
        assert quantize_severity(0.5) == 1.0     # never below neutral

    def test_nan_maps_to_neutral(self):
        assert quantize_severity(float("nan")) == 1.0

    @pytest.mark.parametrize("factor", [float("inf"), 1e308, MAX_SEVERITY,
                                        MAX_SEVERITY + 1.0])
    def test_overflow_clamps_to_ceiling(self, factor):
        # round(inf * 16) used to raise OverflowError mid-reschedule.
        assert quantize_severity(factor) == MAX_SEVERITY


# ---------------------------------------------------------------------------
# telemetry window
# ---------------------------------------------------------------------------

class TestSampleWindow:
    def test_rejects_poison_at_the_door(self):
        w = SampleWindow(maxlen=8)
        for bad in [(float("nan"), 0.5, 1.2), (0.5, float("inf"), 1.2),
                    (0.5, 0.5, float("nan")), (-0.1, 0.5, 1.2),
                    (0.5, -0.5, 1.2), (0.5, 0.5, 0.0)]:
            assert w.observe(*bad) is False
        assert len(w) == 0 and w.rejected == 6

    def test_sub_one_slowdown_clipped(self):
        w = SampleWindow(maxlen=8)
        assert w.observe(0.5, 0.5, 0.9) is True
        assert w.samples()[0][2] == 1.0

    def test_fifo_bound_and_new_counter(self):
        w = SampleWindow(maxlen=8)
        for i in range(12):
            w.observe(0.1, 0.1, 1.0 + i)
        assert len(w) == 8
        assert w.samples()[0][2] == 5.0          # oldest four evicted
        assert w.new_since_fit == 12
        w.mark_fitted()
        assert w.new_since_fit == 0

    def test_min_size_validated(self):
        with pytest.raises(ValueError):
            SampleWindow(maxlen=4)


# ---------------------------------------------------------------------------
# bundle freeze + lineage
# ---------------------------------------------------------------------------

def _tiny_bundle(model=None) -> ProfileBundle:
    plat = xavier_agx()
    model = model or PiecewiseModel(
        (0.0, 0.5, 1.0), (0.0, 0.5, 1.0),
        ((1.0, 1.0, 1.0), (1.0, 1.1, 1.2), (1.0, 1.2, 1.4)))
    return ProfileBundle(platform=plat,
                         graphs=(get_graph("vgg19", plat),),
                         model=model, samples=((0.3, 0.4, 1.1),))


class TestBundleLineage:
    def test_payload_frozen_after_construction(self):
        b = _tiny_bundle()
        with pytest.raises(AttributeError, match="frozen"):
            b.model = ProportionalShareModel()
        with pytest.raises(AttributeError, match="frozen"):
            b.samples = ()
        b.provenance["note"] = "metadata stays writable"

    def test_stale_hash_impossible_via_derive(self):
        # the freeze is what guarantees save() never emits a stale hash:
        # hash once, derive, and both hashes must still verify.
        b = _tiny_bundle()
        h0 = b.bundle_hash()
        child = b.derive(model=ProportionalShareModel(capacity=0.8))
        assert b.bundle_hash() == h0
        assert child.parent_hash == h0
        assert child.bundle_hash() != h0

    def test_parent_hash_omitted_for_roots(self):
        # pre-lineage format-1 hashes must stay valid: a root bundle's
        # payload carries no parent_hash key at all.
        b = _tiny_bundle()
        assert "parent_hash" not in b.payload_dict()
        assert "parent_hash" in b.derive().payload_dict()

    def test_lineage_round_trips_through_json(self):
        root = _tiny_bundle()
        mid = root.derive(model=ProportionalShareModel(capacity=0.9))
        head = mid.derive(model=ProportionalShareModel(capacity=0.7))
        chain = [ProfileBundle.from_json(b.to_json())
                 for b in (root, mid, head)]
        verify_lineage(chain)

    def test_broken_link_detected(self):
        root = _tiny_bundle()
        other = root.derive(model=ProportionalShareModel(capacity=0.5))
        stranger = other.derive()
        with pytest.raises(ValueError, match="lineage"):
            verify_lineage([root, stranger])


# ---------------------------------------------------------------------------
# warm-start re-fit
# ---------------------------------------------------------------------------

class TestWarmStartFit:
    def test_knot_geometry_is_fixed(self):
        prev = PiecewiseModel(
            (0.0, 0.4, 1.0), (0.0, 0.6, 1.2),
            ((1.0, 1.0, 1.1), (1.0, 1.2, 1.4), (1.1, 1.4, 1.8)))
        rng = np.random.default_rng(5)
        own = rng.uniform(0.1, 0.9, 80)
        ext = rng.uniform(0.1, 1.1, 80)
        sd = [prev.slowdown(o, e) * 1.3 for o, e in zip(own, ext)]
        r = fit_piecewise(list(zip(own, ext, sd)), warm_start=prev,
                          steps=200)
        assert r.model.own_knots == prev.own_knots
        assert r.model.ext_knots == prev.ext_knots

    def test_warm_start_rejects_explicit_knots(self):
        prev = PiecewiseModel((0.0, 1.0), (0.0, 1.0),
                              ((1.0, 1.2), (1.1, 1.5)))
        with pytest.raises(ValueError, match="warm_start"):
            fit_piecewise([(0.5, 0.5, 1.2)], warm_start=prev,
                          own_knots=(0.0, 1.0))

    def test_polish_tracks_drifted_surface(self):
        # samples drawn from a uniformly-inflated surface: the warm-started
        # polish must follow the drift where evidence exists.
        prev = PiecewiseModel(
            (0.0, 0.5, 1.0), (0.0, 0.5, 1.0),
            ((1.0, 1.1, 1.2), (1.1, 1.3, 1.5), (1.2, 1.5, 1.9)))
        rng = np.random.default_rng(6)
        own = rng.uniform(0.05, 0.95, 200)
        ext = rng.uniform(0.05, 0.95, 200)
        sd = [1.0 + 1.5 * (prev.slowdown(o, e) - 1.0)
              for o, e in zip(own, ext)]
        r = fit_piecewise(list(zip(own, ext, sd)), warm_start=prev,
                          steps=600, lr=0.05, anchor_weight=1e-4)
        pred = [r.model.slowdown(o, e) for o, e in zip(own, ext)]
        err = np.max(np.abs(np.asarray(pred) - np.asarray(sd))
                     / np.asarray(sd))
        assert err < 0.05


# ---------------------------------------------------------------------------
# streaming recalibrator
# ---------------------------------------------------------------------------

class TestStreamingRecalibrator:
    def _drifted(self, n):
        truth = ProportionalShareModel(capacity=0.6, sensitivity=2.0)
        rng = np.random.default_rng(7)
        own = rng.uniform(0.1, 0.9, n)
        ext = rng.uniform(0.1, 0.9, n)
        return truth, [(o, e, truth.slowdown(o, e))
                       for o, e in zip(own, ext)]

    def test_step_gates_on_evidence(self):
        rec = StreamingRecalibrator(_tiny_bundle(), window=64,
                                    min_samples=16, min_new=8,
                                    refit_steps=50)
        assert rec.step() is None                # empty window
        _, samples = self._drifted(15)
        for s in samples:
            rec.observe(*s)
        assert rec.step() is None                # below min_samples
        rec.observe(0.5, 0.5, 1.3)
        assert rec.step() is not None            # 16 samples, 16 new
        assert rec.step() is None                # no new evidence yet

    def test_lineage_grows_and_verifies(self):
        root = _tiny_bundle()
        rec = StreamingRecalibrator(root, window=64, min_samples=16,
                                    min_new=8, refit_steps=50)
        _, samples = self._drifted(48)
        published = 0
        for s in samples:
            rec.observe(*s)
            if rec.step() is not None:
                published += 1
        assert published >= 2 and rec.refits == published
        assert len(rec.lineage) == published + 1
        assert rec.lineage[0] is root
        verify_lineage(rec.lineage)
        assert rec.events[-1].bundle_hash == rec.bundle.bundle_hash()
        assert rec.bundle.provenance["refit"]["seq"] == published

    def test_proportional_seed_refits_to_drifted_truth(self):
        seed = _tiny_bundle(
            model=ProportionalShareModel(capacity=1.0, sensitivity=1.0))
        rec = StreamingRecalibrator(seed, window=256, min_samples=64,
                                    min_new=32, refit_steps=400)
        truth, samples = self._drifted(256)
        for s in samples:
            rec.observe(*s)
        assert rec.step() is not None
        assert rec.max_rel_err_against(truth) < 0.05

    def test_poisoned_telemetry_never_reaches_the_fit(self):
        rec = StreamingRecalibrator(_tiny_bundle(), window=64,
                                    min_samples=16, min_new=8)
        assert rec.observe(float("nan"), 0.5, 1.5) is False
        assert rec.observe(0.5, 0.5, float("inf")) is False
        assert rec._window.rejected == 2 and len(rec._window) == 0


# ---------------------------------------------------------------------------
# throttle state machine + duty token bucket
# ---------------------------------------------------------------------------

class TestTenantThrottle:
    def test_hysteresis_no_flap_at_boundary(self):
        th = TenantThrottle(enter_miss_rate=0.5, exit_miss_rate=0.1,
                            patience=4, alpha=0.5)
        # alternate hit/miss: EWMA hovers near 0.5, never `patience`
        # consecutive strikes on either edge -> zero switches.
        for i in range(100):
            assert th.observe(i % 2 == 0) is None
        assert th.switches == 0 and not th.throttled

    def test_engage_then_sustained_recovery_releases(self):
        th = TenantThrottle(enter_miss_rate=0.5, exit_miss_rate=0.1,
                            patience=3, alpha=0.5)
        actions = [th.observe(True) for _ in range(6)]
        assert "throttle" in actions and th.throttled
        actions = [th.observe(False) for _ in range(12)]
        assert "release" in actions and not th.throttled
        assert th.switches == 2

    def test_hold_pins_engaged_throttle(self):
        th = TenantThrottle(enter_miss_rate=0.5, exit_miss_rate=0.1,
                            patience=3, alpha=0.5)
        assert th.engage() is True
        # miss rate decays to ~0 but the pressure persists: held.
        for _ in range(50):
            assert th.observe(False, hold=True) is None
        assert th.throttled
        # pressure clears: hysteresis release proceeds.
        actions = [th.observe(False) for _ in range(6)]
        assert "release" in actions and not th.throttled

    def test_engage_is_idempotent_and_seeds_ewma(self):
        th = TenantThrottle()
        assert th.engage() is True
        assert th.miss_ewma == 1.0 and th.switches == 1
        assert th.engage() is False              # already engaged
        assert th.switches == 1

    def test_validates_hysteresis_gap(self):
        with pytest.raises(ValueError, match="hysteresis"):
            TenantThrottle(enter_miss_rate=0.3, exit_miss_rate=0.3)
        with pytest.raises(ValueError, match="patience"):
            TenantThrottle(patience=0)


class TestDutyTokenBucket:
    def test_half_duty_strictly_alternates(self):
        c = AdmissionController()
        c.set_duty(3, 0.5)
        got = [c.duty_admit(3) for _ in range(8)]
        assert got == [False, True] * 4
        assert c.throttled == 4

    def test_duty_is_exact_over_long_runs(self):
        c = AdmissionController()
        c.set_duty(0, 0.25)
        admitted = sum(c.duty_admit(0) for _ in range(1000))
        assert admitted == 250

    def test_unthrottled_tenants_unaffected(self):
        c = AdmissionController()
        c.set_duty(1, 0.5)
        assert all(c.duty_admit(2) for _ in range(10))
        assert c.duty_of(2) == 1.0 and c.duty_of(1) == 0.5

    def test_clear_resets_bucket(self):
        c = AdmissionController()
        c.set_duty(0, 0.5)
        c.duty_admit(0)
        c.set_duty(0, 1.0)
        assert c.duty == {} and all(c.duty_admit(0) for _ in range(4))
        with pytest.raises(ValueError):
            c.set_duty(0, 0.0)

    def test_metrics_carry_duty_state(self):
        c = AdmissionController()
        c.set_duty(7, 0.5)
        c.duty_admit(7)
        m = c.metrics()
        assert m["throttled"] == 1 and m["duty"] == {7: 0.5}


# ---------------------------------------------------------------------------
# closed loop end-to-end (small drift injection)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def closed_loop_report():
    specs = [TenantSpec("stable", configs.get("stablelm-1.6b"),
                        max_slots=2, capacity=256, prompt_len=64,
                        max_new=16),
             TenantSpec("llama", configs.get("llama3.2-3b"),
                        max_slots=2, capacity=256, prompt_len=64,
                        max_new=16)]
    plats = [tpu_pod_split(1, 3, name="p13"),
             tpu_pod_split(2, 2, name="p22")]
    pool = build_pool(specs, plats,
                      GatewayConfig(max_transitions=1, body_groups=1),
                      slots=4, deadline_s=5.0)
    trace = poisson_trace(150.0, 1500, 12, seed=3)
    end_ms = float(trace.t_ms[-1])
    cfg = FleetConfig(default_slo=SLO(p99_ms=120.0),
                      slowdown_threshold=1.2, patience=4, cooldown=64,
                      reschedule_budget_s=0.05, throttle=True,
                      throttle_duty=0.5, throttle_margin=0.5)
    recal = StreamingRecalibrator(_tiny_bundle(), window=128,
                                  min_samples=32, min_new=32,
                                  refit_steps=80)
    # ground-truth oracle: constant 1.6x once the antagonist arrives.
    oracle = lambda pp, ext: np.full(len(pp.class_demand), 1.0 + 2.0 * ext)
    gw = FleetGateway(pool, n_tenants=12, cfg=cfg,
                      capacity_hint=len(trace), recalibrator=recal,
                      contention_oracle=oracle)
    demand = [(0.3 * end_ms, p, 0.3) for p in range(len(pool))]
    rep = gw.replay(trace, demand_events=demand)
    return gw, rep, recal


class TestClosedLoopSmoke:
    def test_monitor_fires_and_refits_publish(self, closed_loop_report):
        _, rep, recal = closed_loop_report
        assert len(rep.reschedules) >= 1
        assert recal.refits >= 1
        assert len(rep.recalibrations) == recal.refits

    def test_lineage_verifies_back_to_root(self, closed_loop_report):
        _, _, recal = closed_loop_report
        verify_lineage(recal.lineage)
        assert recal.lineage[0].parent_hash is None
        assert len(recal.lineage) == recal.refits + 1

    def test_published_model_adopted_by_every_plan(self, closed_loop_report):
        gw, _, recal = closed_loop_report
        for pp in gw.pool:
            assert pp.scheduler.model is recal.bundle.model

    def test_throttle_engaged_and_requests_gated(self, closed_loop_report):
        gw, rep, _ = closed_loop_report
        assert any(a == "throttle" for _, _, a in rep.throttle_events)
        assert rep.throttled > 0
        status = gw._rec.status[:gw._rec.n]
        assert (status == THROTTLED).sum() == rep.throttled
        assert (status == DONE).sum() == rep.completed

    def test_telemetry_reached_the_window(self, closed_loop_report):
        _, _, recal = closed_loop_report
        assert len(recal._window) > 0
        # every sample carries the injected ext coordinate, stamped at
        # service start.
        assert all(e == pytest.approx(0.3)
                   for _, e, _ in recal._window.samples())

    def test_report_accounting_consistent(self, closed_loop_report):
        _, rep, _ = closed_loop_report
        slo = rep.slo_report()
        assert slo["throttled"] == rep.throttled
        assert rep.completed + rep.shed + rep.throttled <= rep.n_requests

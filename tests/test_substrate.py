"""Substrate tests: optimizer, pipeline determinism, checkpoint/restart,
fault tolerance, elastic restore, serving engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import build
from repro.serve.engine import ServingEngine
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train.trainer import Trainer


class TestOptimizers:
    def quad(self, opt, steps=200):
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        for step in range(steps):
            grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
            upd, state = opt.update(grads, state, params, step)
            params = jax.tree.map(lambda p, u: p + u, params, upd)
        return float(jnp.abs(params["w"]).max())

    def test_adamw_converges(self):
        assert self.quad(opt_lib.adamw(1e-1, weight_decay=0.0)) < 1e-2

    def test_adafactor_converges(self):
        assert self.quad(opt_lib.adafactor(1e-1)) < 5e-2

    def test_adafactor_factored_state_is_small(self):
        opt = opt_lib.adafactor(1e-3)
        params = {"w": jnp.zeros((128, 64))}
        st = opt.init(params)
        n_state = sum(x.size for x in jax.tree.leaves(st))
        assert n_state == 128 + 64                   # vs 2*128*64 for adam

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(np.sqrt(10) * 100)
        assert float(opt_lib.global_norm(clipped)) == pytest.approx(1.0)

    def test_warmup_cosine_shape(self):
        lr = opt_lib.warmup_cosine(1e-3, warmup=10, total=100)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
        assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)


class TestPipeline:
    def test_deterministic_per_step(self):
        cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8)
        a = SyntheticLM(cfg).batch_at(7)
        b = SyntheticLM(cfg).batch_at(7)
        np.testing.assert_array_equal(a["token_ids"], b["token_ids"])

    def test_labels_are_next_tokens(self):
        cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4)
        b = SyntheticLM(cfg).batch_at(0)
        np.testing.assert_array_equal(b["token_ids"][:, 1:],
                                      b["labels"][:, :-1])

    def test_rank_shards_disjoint(self):
        cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
        r0 = SyntheticLM(cfg, dp_rank=0, dp_size=2).batch_at(3)
        r1 = SyntheticLM(cfg, dp_rank=1, dp_size=2).batch_at(3)
        assert not np.array_equal(r0["token_ids"], r1["token_ids"])
        assert r0["token_ids"].shape[0] == 4

    def test_vocab_bounded(self):
        cfg = DataConfig(vocab=128, seq_len=64, global_batch=4)
        b = SyntheticLM(cfg).batch_at(11)
        assert b["token_ids"].max() < 128
        assert b["token_ids"].min() >= 0


def tiny_trainer(tmp_path, ckpt_every=5, steps_cfg=None):
    cfg = configs.get("llama3.2-3b").reduced(n_layers=2, vocab=128)
    if steps_cfg:
        cfg = dataclasses.replace(cfg, **steps_cfg)
    model = build(cfg, backend="xla")
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16,
                                  global_batch=4))
    return Trainer(model, data, ckpt_dir=str(tmp_path),
                   ckpt_every=ckpt_every)


class TestTrainerFaultTolerance:
    def test_loss_decreases(self, tmp_path):
        tr = tiny_trainer(tmp_path)
        tr.restore_or_init(jax.random.PRNGKey(0))
        hist = tr.run(30, log_every=5)
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_restart_is_bitwise_resumable(self, tmp_path):
        # uninterrupted run
        tr1 = tiny_trainer(tmp_path / "a", ckpt_every=100)
        tr1.restore_or_init(jax.random.PRNGKey(0))
        tr1.run(12, log_every=100)
        final1 = jax.tree.leaves(tr1.state.params)

        # interrupted at step 6, restarted from checkpoint
        tr2 = tiny_trainer(tmp_path / "b", ckpt_every=6)
        tr2.restore_or_init(jax.random.PRNGKey(0))
        tr2.run(6, log_every=100)
        tr3 = tiny_trainer(tmp_path / "b", ckpt_every=6)
        tr3.restore_or_init(jax.random.PRNGKey(99))   # key ignored: restores
        assert int(tr3.state.step) == 6
        tr3.run(12, log_every=100)
        final3 = jax.tree.leaves(tr3.state.params)
        for a, b in zip(final1, final3):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=1e-6)

    def test_checkpoint_atomic_and_gc(self, tmp_path):
        tr = tiny_trainer(tmp_path, ckpt_every=2)
        tr.restore_or_init(jax.random.PRNGKey(0))
        tr.run(10, log_every=100)
        ckpts = sorted(tmp_path.glob("ckpt_*.npz"))
        assert len(ckpts) <= 3                      # keep=3 rolling
        assert ckpt_lib.latest_step(tmp_path) == 10

    def test_restore_shape_mismatch_rejected(self, tmp_path):
        tr = tiny_trainer(tmp_path, ckpt_every=2)
        tr.restore_or_init(jax.random.PRNGKey(0))
        tr.run(2, log_every=100)
        bad = {"x": jnp.zeros((3, 3))}
        with pytest.raises((ValueError, KeyError)):
            ckpt_lib.restore(tmp_path, bad)


class TestElasticRestore:
    def test_checkpoint_is_mesh_agnostic(self, tmp_path):
        """Save from a 'large DP' run, restore into a different DP size —
        arrays are stored unsharded, so elastic rescale is a reshard."""
        cfg = configs.get("stablelm-1.6b").reduced(n_layers=2, vocab=64)
        model = build(cfg, backend="xla")
        params = model.init(jax.random.PRNGKey(1))
        ckpt_lib.save(tmp_path, 5, params)
        like = model.abstract_params()
        restored, step = ckpt_lib.restore(tmp_path, like)
        assert step == 5
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestServingEngine:
    @pytest.fixture(scope="class")
    def engine_setup(self):
        cfg = configs.get("llama3.2-3b").reduced(n_layers=2, vocab=64)
        model = build(cfg, backend="xla")
        params = model.init(jax.random.PRNGKey(0))
        return cfg, model, params

    def test_batched_requests_complete(self, engine_setup):
        cfg, model, params = engine_setup
        eng = ServingEngine(model, params, max_slots=3, capacity=64)
        reqs = [eng.submit(np.arange(4 + i) % cfg.vocab, max_new=5)
                for i in range(5)]
        done = eng.run_until_drained()
        assert len(done) == 5
        assert all(len(r.tokens) == 5 for r in reqs)

    def test_continuous_batching_matches_sequential(self, engine_setup):
        """Tokens generated under continuous batching equal those generated
        one-request-at-a-time (slot interference would corrupt caches)."""
        cfg, model, params = engine_setup
        prompts = [np.arange(5) % cfg.vocab, (np.arange(7) * 3) % cfg.vocab]
        # sequential singles
        singles = []
        for p in prompts:
            e = ServingEngine(model, params, max_slots=1, capacity=64)
            r = e.submit(p, max_new=4)
            e.run_until_drained()
            singles.append(r.tokens)
        # batched together
        e2 = ServingEngine(model, params, max_slots=2, capacity=64)
        rs = [e2.submit(p, max_new=4) for p in prompts]
        e2.run_until_drained()
        for got, want in zip([r.tokens for r in rs], singles):
            assert got == want

"""Model-component unit tests: norms, rope, MoE dispatch, losses."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _prop import given, settings, st

from repro import configs
from repro.models import moe as moe_mod
from repro.models.layers import cross_entropy, rmsnorm, rope


class TestRMSNorm:
    def test_unit_scale_normalizes(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32)) * 7.0
        y = rmsnorm(x, jnp.zeros((32,)))
        rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
        np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


class TestRoPE:
    def test_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 64))
        pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
        y = rope(x, pos, 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(y), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)

    def test_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 64))

        def dot(i, j):
            qi = rope(q, jnp.array([[i]]), 10000.0)
            kj = rope(k, jnp.array([[j]]), 10000.0)
            return float(jnp.sum(qi * kj))

        assert dot(5, 3) == pytest.approx(dot(12, 10), rel=1e-4)
        assert dot(0, 0) == pytest.approx(dot(9, 9), rel=1e-4)


class TestMoE:
    @pytest.mark.parametrize("seed", range(3))
    @pytest.mark.parametrize("arch", ["dbrx-132b", "qwen3-moe-235b-a22b"])
    def test_matches_dense_reference(self, arch, seed):
        """Sort-based dispatch == per-token dense expert evaluation."""
        cfg = configs.get(arch).reduced()
        p, _ = moe_mod.init_moe(cfg, jax.random.PRNGKey(seed))
        x = jax.random.normal(jax.random.PRNGKey(seed + 10),
                              (2, 7, cfg.d_model))

        def ref(x):
            B, S, d = x.shape
            h = rmsnorm(x, p["ln"]).reshape(B * S, d)
            probs = jax.nn.softmax(h @ p["router"], -1)
            gv, ei = jax.lax.top_k(probs, cfg.moe.top_k)
            gv = gv / gv.sum(-1, keepdims=True)
            out = jnp.zeros_like(h)
            for t in range(B * S):
                for j in range(cfg.moe.top_k):
                    e = int(ei[t, j])
                    act = (jax.nn.silu(h[t] @ p["wi_gate"][e])
                           * (h[t] @ p["wi"][e]))
                    out = out.at[t].add(gv[t, j] * (act @ p["wo"][e]))
            return x + out.reshape(B, S, d)

        got, aux = moe_mod.moe_block(cfg, p, {}, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref(x)),
                                   atol=1e-5, rtol=1e-5)
        assert float(aux["moe_aux"]) >= 0

    def test_capacity_drops_fall_back_to_residual(self):
        cfg = configs.get("dbrx-132b").reduced()
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.01))
        p, _ = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        y, _ = moe_mod.moe_block(cfg, p, {}, x)
        # with capacity ~0 nearly everything is dropped -> y ~= x
        assert float(jnp.abs(y - x).max()) < float(jnp.abs(x).max())

    def test_balanced_router_minimizes_aux(self):
        cfg = configs.get("dbrx-132b").reduced()
        p, _ = moe_mod.init_moe(cfg, jax.random.PRNGKey(0))
        # uniform router -> aux loss ~= weight (its minimum is at balance)
        p = dict(p, router=jnp.zeros_like(p["router"]))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
        _, aux = moe_mod.moe_block(cfg, p, {}, x)
        assert float(aux["moe_aux"]) == pytest.approx(
            cfg.moe.aux_loss_weight, rel=0.05)


class TestCrossEntropy:
    def test_uniform_logits_log_vocab(self):
        cfg = configs.get("llama3.2-3b").reduced()
        V = 64
        logits = jnp.zeros((2, 8, V))
        labels = jnp.zeros((2, 8), jnp.int32)
        loss, m = cross_entropy(
            dataclasses.replace(cfg, z_loss=0.0), logits, labels)
        assert float(loss) == pytest.approx(np.log(V), rel=1e-5)

    def test_mask_excludes_tokens(self):
        cfg = dataclasses.replace(configs.get("llama3.2-3b").reduced(),
                                  z_loss=0.0)
        logits = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 32))
        labels = jnp.zeros((1, 6), jnp.int32)
        mask = jnp.array([[1, 1, 1, 0, 0, 0]], jnp.float32)
        full, _ = cross_entropy(cfg, logits[:, :3], labels[:, :3])
        masked, _ = cross_entropy(cfg, logits, labels, mask)
        assert float(full) == pytest.approx(float(masked), rel=1e-6)


@given(b1=st.floats(0.0, 5.0), b2=st.floats(0.0, 5.0))
@settings(max_examples=30, deadline=None)
def test_rmsnorm_scale_equivariance(b1, b2):
    """rmsnorm(a*x) == rmsnorm(x) for a > 0 (scale invariance)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 16)) + b1
    a = 1.0 + b2
    y1 = rmsnorm(a * x, jnp.zeros((16,)))
    y2 = rmsnorm(x, jnp.zeros((16,)))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)

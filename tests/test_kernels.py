"""Kernel validation: shape/dtype sweeps, every backend vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEYS = jax.random.split(jax.random.PRNGKey(42), 8)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


ATTN_SHAPES = [
    # (B, Sq, Skv, Hq, Hkv, D)
    (1, 128, 128, 4, 4, 64),      # MHA
    (2, 256, 256, 8, 2, 64),      # GQA 4:1
    (1, 64, 64, 4, 1, 128),       # MQA
    (2, 96, 96, 4, 2, 32),        # non-128 seq (masked tail tiles)
]


class TestAttention:
    @pytest.mark.parametrize("shape", ATTN_SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    @pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                               (True, 48)])
    def test_vs_oracle(self, shape, dtype, backend, causal, window):
        B, Sq, Skv, Hq, Hkv, D = shape
        q = rand(KEYS[0], (B, Sq, Hq, D), dtype)
        k = rand(KEYS[1], (B, Skv, Hkv, D), dtype)
        v = rand(KEYS[2], (B, Skv, Hkv, D), dtype)
        got = ops.attention(q, k, v, causal=causal, window=window,
                            backend=backend, block_q=64, block_kv=64)
        want = ref.attention(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(
            got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype))

    def test_decode_offset_queries(self):
        """Sq < Skv: queries are the last Sq positions (chunked prefill)."""
        q = rand(KEYS[0], (2, 32, 4, 64), jnp.float32)
        k = rand(KEYS[1], (2, 128, 4, 64), jnp.float32)
        v = rand(KEYS[2], (2, 128, 4, 64), jnp.float32)
        got = ops.attention(q, k, v, causal=True, backend="xla", block_kv=32)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_grad_flows_xla(self):
        q = rand(KEYS[0], (1, 64, 2, 32), jnp.float32)
        k = rand(KEYS[1], (1, 64, 2, 32), jnp.float32)
        v = rand(KEYS[2], (1, 64, 2, 32), jnp.float32)
        g = jax.grad(lambda q_: ops.attention(
            q_, k, v, backend="xla", block_kv=16).sum())(q)
        assert np.isfinite(np.asarray(g)).all()


class TestDecodeAttention:
    @pytest.mark.parametrize("shape", [(2, 128, 8, 2, 64), (1, 96, 4, 4, 32),
                                       (3, 256, 4, 1, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    def test_vs_oracle(self, shape, dtype, backend):
        B, S, Hq, Hkv, D = shape
        q = rand(KEYS[0], (B, 1, Hq, D), dtype)
        k = rand(KEYS[1], (B, S, Hkv, D), dtype)
        v = rand(KEYS[2], (B, S, Hkv, D), dtype)
        lengths = jnp.array([S // 2 + 7 * i + 1 for i in range(B)],
                            jnp.int32) % S + 1
        got = ops.decode_attention(q, k, v, lengths, backend=backend)
        want = ref.attention(q, k, v, causal=True, lengths=lengths)
        np.testing.assert_allclose(
            got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype))


class TestLinearScan:
    @pytest.mark.parametrize("shape", [(2, 64, 32), (1, 100, 256),
                                       (3, 33, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    @pytest.mark.parametrize("with_h0", [False, True])
    def test_vs_oracle(self, shape, dtype, backend, with_h0):
        B, S, D = shape
        a = jax.nn.sigmoid(rand(KEYS[0], shape, jnp.float32)).astype(dtype)
        b = rand(KEYS[1], shape, dtype)
        h0 = rand(KEYS[2], (B, D), dtype) if with_h0 else None
        h, hT = ops.linear_scan(a, b, h0, backend=backend)
        h_ref, hT_ref = ref.linear_scan(a, b, h0)
        np.testing.assert_allclose(h.astype(jnp.float32),
                                   h_ref.astype(jnp.float32), **tol(dtype))
        np.testing.assert_allclose(np.asarray(hT, np.float32),
                                   np.asarray(hT_ref, np.float32),
                                   **tol(dtype))

    def test_decay_composition_property(self):
        """Scanning [0:k) then [k:S) with carried state == one scan."""
        B, S, D = 2, 48, 16
        a = jax.nn.sigmoid(rand(KEYS[0], (B, S, D), jnp.float32))
        b = rand(KEYS[1], (B, S, D), jnp.float32)
        h_full, hT_full = ops.linear_scan(a, b, backend="xla")
        k = 20
        _, h1 = ops.linear_scan(a[:, :k], b[:, :k], backend="xla")
        h2_all, h2 = ops.linear_scan(a[:, k:], b[:, k:], h1, backend="xla")
        np.testing.assert_allclose(np.asarray(hT_full), np.asarray(h2),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(h_full[:, k:]),
                                   np.asarray(h2_all), atol=1e-5, rtol=1e-5)


class TestRWKV6:
    @pytest.mark.parametrize("shape", [(1, 32, 2, 16, 16), (2, 17, 4, 32, 32),
                                       (1, 64, 1, 64, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    def test_vs_oracle(self, shape, dtype, backend):
        B, T, H, D, Dv = shape
        r = rand(KEYS[0], (B, T, H, D), dtype)
        k = rand(KEYS[1], (B, T, H, D), dtype) * 0.3
        v = rand(KEYS[2], (B, T, H, Dv), dtype)
        w = jax.nn.sigmoid(rand(KEYS[3], (B, T, H, D), jnp.float32) + 2.0
                           ).astype(dtype)
        u = rand(KEYS[4], (H, D), dtype) * 0.3
        s0 = rand(KEYS[5], (B, H, D, Dv), jnp.float32) * 0.1
        y, sT = ops.rwkv6(r, k, v, w, u, s0, backend=backend)
        y_ref, sT_ref = ref.rwkv6(r, k, v, w, u, s0)
        np.testing.assert_allclose(y.astype(jnp.float32),
                                   y_ref.astype(jnp.float32),
                                   atol=5e-2 if dtype == jnp.bfloat16
                                   else 1e-4, rtol=5e-2)
        np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                                   atol=5e-2 if dtype == jnp.bfloat16
                                   else 1e-4, rtol=5e-2)

    def test_state_streaming_property(self):
        """Chunked evaluation with carried state == full evaluation."""
        B, T, H, D, Dv = 1, 40, 2, 16, 16
        r = rand(KEYS[0], (B, T, H, D), jnp.float32)
        k = rand(KEYS[1], (B, T, H, D), jnp.float32) * 0.3
        v = rand(KEYS[2], (B, T, H, Dv), jnp.float32)
        w = jax.nn.sigmoid(rand(KEYS[3], (B, T, H, D), jnp.float32) + 2.0)
        u = rand(KEYS[4], (H, D), jnp.float32) * 0.3
        y_full, s_full = ops.rwkv6(r, k, v, w, u, backend="xla")
        cut = 23
        y1, s1 = ops.rwkv6(r[:, :cut], k[:, :cut], v[:, :cut], w[:, :cut],
                           u, backend="xla")
        y2, s2 = ops.rwkv6(r[:, cut:], k[:, cut:], v[:, cut:], w[:, cut:],
                           u, s1, backend="xla")
        np.testing.assert_allclose(np.asarray(y_full[:, cut:]),
                                   np.asarray(y2), atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                                   atol=1e-5, rtol=1e-5)


class TestSlowdownSurfaceKernel:
    """The batched PCCS slowdown kernel vs the scalar contention model and
    the NumPy surface path (repro.core.lowering.slowdown_array)."""

    def _model(self):
        from repro.core.contention import PiecewiseModel
        return PiecewiseModel(
            (0.2, 0.6, 1.0), (0.2, 0.5, 0.8, 1.1),
            ((1.0, 1.1, 1.3, 1.5), (1.1, 1.4, 1.7, 1.9),
             (1.3, 1.7, 2.2, 2.5)))

    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    def test_vs_numpy_surface_and_scalar_model(self, backend):
        from repro.core.lowering import slowdown_array
        from repro.kernels.slowdown import piecewise_slowdown
        m = self._model()
        rng = np.random.default_rng(0)
        own = rng.uniform(-0.1, 1.4, size=2048)
        ext = rng.uniform(-0.1, 1.4, size=2048)
        want = slowdown_array(m, own, ext)
        got = np.asarray(piecewise_slowdown(
            own.astype(np.float32), ext.astype(np.float32),
            m.own_knots, m.ext_knots, m.table, backend=backend))
        np.testing.assert_allclose(got, want, atol=5e-6, rtol=5e-6)
        # spot-check the scalar model directly, incl. exact knots/corners
        for o, e in [(0.2, 0.5), (0.6, 1.1), (0.0, 0.9), (0.9, 0.0),
                     (2.0, 2.0), (0.05, 0.05), (1.0, 1.1)]:
            g = float(np.asarray(piecewise_slowdown(
                jnp.float32(o)[None], jnp.float32(e)[None],
                m.own_knots, m.ext_knots, m.table, backend=backend))[0])
            assert g == pytest.approx(m.slowdown(o, e), abs=5e-6)

    def test_zero_demand_is_identity(self):
        from repro.kernels.slowdown import piecewise_slowdown
        m = self._model()
        own = jnp.asarray([0.0, 0.5, -1.0])
        ext = jnp.asarray([0.7, 0.0, 0.7])
        out = np.asarray(piecewise_slowdown(own, ext, m.own_knots,
                                            m.ext_knots, m.table,
                                            backend="xla"))
        np.testing.assert_allclose(out, [1.0, 1.0, 1.0])

    def test_nonmultiple_block_padding(self):
        from repro.kernels.slowdown import piecewise_slowdown
        m = self._model()
        rng = np.random.default_rng(1)
        own = rng.uniform(0.05, 1.3, size=777).astype(np.float32)
        ext = rng.uniform(0.05, 1.3, size=777).astype(np.float32)
        a = np.asarray(piecewise_slowdown(own, ext, m.own_knots,
                                          m.ext_knots, m.table,
                                          backend="pallas_interpret",
                                          block=256))
        b = np.asarray(piecewise_slowdown(own, ext, m.own_knots,
                                          m.ext_knots, m.table,
                                          backend="xla"))
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)


class TestSlowdownAutoDispatch:
    """``backend="auto"``: the tiny-batch XLA fallback below the pallas
    launch threshold, and xla/interpret agreement at the boundary."""

    _model = TestSlowdownSurfaceKernel._model

    def _demands(self, n):
        rng = np.random.default_rng(n)
        return (rng.uniform(0.05, 1.3, size=n).astype(np.float32),
                rng.uniform(0.05, 1.3, size=n).astype(np.float32))

    @pytest.mark.parametrize("delta", [-1, 0, +1])
    def test_paths_agree_at_threshold_boundary(self, delta):
        from repro.kernels import ref
        from repro.kernels.slowdown import (_MIN_PALLAS_ELEMS,
                                            piecewise_slowdown)
        m = self._model()
        own, ext = self._demands(_MIN_PALLAS_ELEMS + delta)
        want = np.asarray(ref.piecewise_slowdown(
            own, ext, np.asarray(m.own_knots, np.float32),
            np.asarray(m.ext_knots, np.float32),
            np.asarray(m.table, np.float32)))
        for backend in ("auto", "xla", "pallas_interpret"):
            got = np.asarray(piecewise_slowdown(
                own, ext, m.own_knots, m.ext_knots, m.table,
                backend=backend))
            np.testing.assert_allclose(got, want, atol=5e-6, rtol=5e-6,
                                       err_msg=f"backend={backend} "
                                               f"n={len(own)}")

    def test_auto_prefers_xla_below_threshold_on_tpu(self, monkeypatch):
        """Even on TPU, auto must not pay a pallas launch for a tiny
        batch — below _MIN_PALLAS_ELEMS it stays on the fused XLA path."""
        from repro.kernels import slowdown
        calls = []
        real = slowdown._pallas_piecewise

        def recording(*args, **kwargs):
            calls.append(kwargs.get("interpret"))
            # run interpreted so the dispatch decision is testable on CPU
            kwargs["interpret"] = True
            return real(*args, **kwargs)

        monkeypatch.setattr(slowdown, "_pallas_piecewise", recording)
        monkeypatch.setattr(slowdown.jax, "default_backend",
                            lambda: "tpu")
        m = self._model()
        small = self._demands(slowdown._MIN_PALLAS_ELEMS - 1)
        slowdown.piecewise_slowdown(*small, m.own_knots, m.ext_knots,
                                    m.table, backend="auto")
        assert not calls, "tiny batch must take the XLA fallback"
        big = self._demands(slowdown._MIN_PALLAS_ELEMS)
        slowdown.piecewise_slowdown(*big, m.own_knots, m.ext_knots,
                                    m.table, backend="auto")
        assert len(calls) == 1, "at-threshold batch must launch pallas"

    def test_auto_is_xla_off_tpu_regardless_of_size(self, monkeypatch):
        from repro.kernels import slowdown
        monkeypatch.setattr(
            slowdown, "_pallas_piecewise",
            lambda *a, **k: pytest.fail("pallas launched off-TPU"))
        m = self._model()
        own, ext = self._demands(slowdown._MIN_PALLAS_ELEMS * 2)
        out = slowdown.piecewise_slowdown(own, ext, m.own_knots,
                                          m.ext_knots, m.table,
                                          backend="auto")
        assert out.shape == own.shape

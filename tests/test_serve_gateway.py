"""Multi-tenant gateway: planning, admission, budget, dynamic re-schedule,
and the single-model engine regression after the step()/metrics refactor."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.core import Plan, Scheduler
from repro.core.accelerators import tpu_pod_split
from repro.core.contention import ProportionalShareModel
from repro.core.dynamic import ScaledContentionModel, SlowdownMonitor
from repro.models import build
from repro.serve.engine import ServingEngine
from repro.serve.gateway import (GatewayConfig, MultiTenantGateway,
                                 TenantSpec, kv_bytes_per_token,
                                 plan_gateway, tenant_phase_graph)

STABLE = configs.get("stablelm-1.6b").reduced()
LLAMA = configs.get("llama3.2-3b").reduced()
PLAT = tpu_pod_split(2, 2, name="v5e-2x2-test")


def _gcfg(**kw):
    kw.setdefault("platform", PLAT)
    kw.setdefault("max_transitions", 1)
    kw.setdefault("body_groups", 1)
    return GatewayConfig(**kw)


def _specs(max_slots=2, capacity=32):
    return [TenantSpec("stable", STABLE, max_slots=max_slots,
                       capacity=capacity, prompt_len=5, max_new=4),
            TenantSpec("llama", LLAMA, max_slots=max_slots,
                       capacity=capacity, prompt_len=5, max_new=4)]


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

class TestPlanning:
    def test_phase_graph_structure(self):
        g = tenant_phase_graph(_specs()[0], PLAT, body_groups=1)
        names = [gr.name for gr in g.groups]
        n_pf = sum(1 for n in names if n.startswith("prefill:"))
        n_dc = sum(1 for n in names if n.startswith("decode:"))
        assert n_pf == n_dc == 3            # embed + body + head per phase
        assert names[:n_pf] == [n for n in names if n.startswith("prefill:")]

    def test_decode_macro_group_scales_with_max_new(self):
        s1 = TenantSpec("t", STABLE, prompt_len=5, max_new=1)
        s8 = TenantSpec("t", STABLE, prompt_len=5, max_new=8)
        g1 = tenant_phase_graph(s1, PLAT, body_groups=1)
        g8 = tenant_phase_graph(s8, PLAT, body_groups=1)
        acc = PLAT.names[0]
        d1 = [gr for gr in g1.groups if gr.name.startswith("decode:")]
        d8 = [gr for gr in g8.groups if gr.name.startswith("decode:")]
        for a, b in zip(d1, d8):
            assert b.time_on(acc) == pytest.approx(8 * a.time_on(acc))
            # demand is a rate: unchanged by the macro-group fusion
            assert b.demand_on(acc) == pytest.approx(a.demand_on(acc))

    def test_plan_no_worse_than_round_robin(self):
        plan = plan_gateway(_specs(), _gcfg())
        assert plan.speedup_vs_round_robin >= 1.0 - 1e-9
        assert plan.summary()

    def test_phase_assignments_cover_graph(self):
        plan = plan_gateway(_specs(), _gcfg())
        for s in plan.specs:
            ph = plan.phase_assignment(s.name)
            total = len(ph["prefill"]) + len(ph["decode"])
            assert total == len(plan.graphs[plan._idx(s.name)])
            assert plan.predicted_decode_step_ms(s.name) > 0.0

    def test_serialized_plan_boots_gateway_with_zero_solves(self, tmp_path):
        """Pre-solve offline, reload the artifact, re-plan: cache hit only."""
        s1 = Scheduler(PLAT)
        plan1 = plan_gateway(_specs(), _gcfg(), scheduler=s1)
        assert s1.solves == 1
        path = plan1.plan.save(tmp_path / "gw.json")

        s2 = Scheduler(PLAT)
        s2.cache.add(Plan.load(path))
        plan2 = plan_gateway(_specs(), _gcfg(), scheduler=s2)
        assert s2.solves == 0 and s2.cache.hits == 1
        assert plan2.solution.assignments == plan1.solution.assignments
        assert plan2.plan.request_hash == plan1.plan.request_hash

    def test_shared_scheduler_caches_across_gateways(self):
        sched = Scheduler(PLAT)
        MultiTenantGateway(_specs(), _gcfg(), scheduler=sched)
        MultiTenantGateway(_specs(), _gcfg(), scheduler=sched)
        assert sched.solves == 1 and sched.cache.hits >= 1


# ---------------------------------------------------------------------------
# runtime: multi-model admission + shared budget
# ---------------------------------------------------------------------------

class TestGatewayServing:
    def test_serves_two_models_concurrently(self):
        gw = MultiTenantGateway(_specs(), _gcfg())
        rng = np.random.default_rng(0)
        for name in gw.specs:
            for _ in range(3):
                gw.submit(name, rng.integers(0, 256, size=5))
        saw_both_active = False
        while gw.has_work and gw.total_steps < 200:
            rep = gw.step(observed_ms={"stable": 1.0, "llama": 1.0})
            if all(v > 0 for v in rep.active.values()):
                saw_both_active = True
        done = {n: e.completed for n, e in gw.engines.items()}
        assert saw_both_active, "tenants never decoded in the same step"
        for name, reqs in done.items():
            assert len(reqs) == 3
            # prefill emits the first token, decode the rest: max_new total
            assert all(len(r.tokens) == 4 for r in reqs)

    def test_memory_budget_enforced(self):
        specs = _specs()
        one_slot = max(s.kv_bytes_per_slot for s in specs)
        gw = MultiTenantGateway(specs, _gcfg(memory_budget_bytes=one_slot))
        rng = np.random.default_rng(1)
        for name in gw.specs:
            for _ in range(2):
                gw.submit(name, rng.integers(0, 256, size=5))
        while gw.has_work and gw.total_steps < 400:
            gw.step(observed_ms={"stable": 1.0, "llama": 1.0})
            assert gw.kv_bytes_in_use <= one_slot
            assert sum(e.active for e in gw.engines.values()) <= 1
        assert gw.deferred_admissions > 0
        # throttled, not starved: everything still completes
        assert all(len(e.completed) == 2 for e in gw.engines.values())

    def test_rejects_encoder_only_tenant(self):
        hubert = configs.get("hubert-xlarge").reduced()
        with pytest.raises(ValueError, match="encoder-only"):
            MultiTenantGateway([TenantSpec("enc", hubert)], _gcfg())

    def test_kv_bytes_per_token(self):
        n_attn = sum(1 for k in STABLE.layer_kinds if k in ("attn", "local"))
        assert kv_bytes_per_token(STABLE) == (
            2 * STABLE.n_kv_heads * STABLE.d_head * 4 * n_attn)  # float32


# ---------------------------------------------------------------------------
# dynamic loop
# ---------------------------------------------------------------------------

class TestDynamicReschedule:
    def test_injected_slowdown_triggers_reschedule(self):
        gw = MultiTenantGateway(_specs(), _gcfg(patience=2, cooldown=2,
                                                warmup=1))
        rng = np.random.default_rng(2)
        for name in gw.specs:
            for _ in range(2):
                gw.submit(name, rng.integers(0, 256, size=5), max_new=12)
        fired_for = set()
        while gw.has_work and gw.total_steps < 400:
            llama_ms = 10.0 if gw.total_steps >= 4 else 1.0
            rep = gw.step(observed_ms={"stable": 1.0, "llama": llama_ms})
            fired_for.update(rep.fired)
        assert "llama" in fired_for
        assert "stable" not in fired_for
        assert gw.reschedules
        ev = gw.reschedules[0]
        assert "llama" in ev.tenants
        assert ev.observed_factor > gw.gcfg.slowdown_threshold
        # re-solve under the scaled model keeps a valid full assignment
        for wl in gw.plan.solution.workloads:
            assert len(wl.assignment) == len(wl.graph)

    def test_on_prediction_stream_never_fires(self):
        gw = MultiTenantGateway(_specs(), _gcfg(patience=2, cooldown=2))
        rng = np.random.default_rng(3)
        for name in gw.specs:
            gw.submit(name, rng.integers(0, 256, size=5))
        while gw.has_work and gw.total_steps < 200:
            rep = gw.step(observed_ms={"stable": 1.0, "llama": 1.0})
            assert not rep.fired
        assert not gw.reschedules


class TestSlowdownMonitor:
    def test_fires_after_patience_and_cools_down(self):
        m = SlowdownMonitor(threshold=1.5, patience=2, cooldown=3,
                            warmup=0, alpha=1.0)
        assert not m.observe(1.0, 1.0)
        assert not m.observe(2.0, 1.0)      # strike 1
        assert m.observe(2.0, 1.0)          # strike 2 -> fire
        assert m.fired == 1
        for _ in range(3):                  # cooldown holds
            assert not m.observe(2.0, 1.0)
        assert not m.observe(2.0, 1.0)      # strike 1 again
        assert m.observe(2.0, 1.0)          # fire again
        assert m.fired == 2

    def test_running_fast_never_fires(self):
        m = SlowdownMonitor(threshold=1.2, patience=1, warmup=0, alpha=1.0)
        for _ in range(20):
            assert not m.observe(0.5, 1.0)

    def test_warmup_absorbs_compile_spike(self):
        m = SlowdownMonitor(threshold=1.5, patience=1, cooldown=0,
                            warmup=2, alpha=1.0)
        assert not m.observe(50.0, 1.0)     # JIT compile step
        assert not m.observe(50.0, 1.0)
        assert not m.observe(1.0, 1.0)      # steady state
        assert m.observe(3.0, 1.0)          # real deviation fires

    def test_invalid_observations_ignored(self):
        m = SlowdownMonitor(warmup=0)
        assert not m.observe(1.0, 0.0)
        assert not m.observe(-1.0, 1.0)
        assert m.ratio == 1.0

    def test_scaled_model_scales_excess_only(self):
        base = ProportionalShareModel(capacity=1.0, sensitivity=1.0)
        scaled = ScaledContentionModel(base, factor=3.0)
        assert scaled.slowdown(0.2, 0.2) == 1.0          # under capacity
        excess = base.slowdown(0.8, 0.8) - 1.0
        assert scaled.slowdown(0.8, 0.8) == pytest.approx(1.0 + 3 * excess)


# ---------------------------------------------------------------------------
# regression: the refactor must not change single-model engine behavior
# ---------------------------------------------------------------------------

class TestEngineRegression:
    def test_single_model_output_unchanged_via_gateway(self):
        rng = np.random.default_rng(4)
        prompts = [rng.integers(0, 256, size=5) for _ in range(3)]

        model = build(STABLE)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServingEngine(model, params, max_slots=2, capacity=32)
        for p in prompts:
            eng.submit(p, max_new=4)
        direct = sorted((r.rid, r.tokens) for r in eng.run_until_drained())

        spec = TenantSpec("solo", STABLE, max_slots=2, capacity=32,
                          prompt_len=5, max_new=4)
        gw = MultiTenantGateway([spec], _gcfg(), seed=0)
        for p in prompts:
            gw.submit("solo", p)
        via_gw = sorted((r.rid, r.tokens)
                        for r in gw.run_until_drained()["solo"])
        assert via_gw == direct

    def test_engine_metrics_and_has_work(self):
        model = build(STABLE)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServingEngine(model, params, max_slots=2, capacity=32)
        assert not eng.has_work
        eng.submit(np.arange(5), max_new=3)
        assert eng.has_work
        eng.run_until_drained()
        assert not eng.has_work
        assert eng.counters.admitted == 1
        assert eng.counters.steps == eng.steps > 0
        assert eng.counters.tokens_out == 3
        assert eng.counters.last_step_ms > 0.0
        assert eng.counters.mean_step_ms > 0.0

    def test_metrics_dict_is_canonical_shape(self):
        from repro.serve.engine import METRIC_KEYS
        model = build(STABLE)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServingEngine(model, params, max_slots=2, capacity=32)
        eng.submit(np.arange(5), max_new=3)
        eng.run_until_drained()
        m = eng.metrics()
        assert tuple(m) == METRIC_KEYS
        assert m["completed"] == 1 and m["deferred"] == 0

    def test_gateway_metrics_reuses_engine_shape(self):
        from repro.serve.engine import METRIC_KEYS
        spec = TenantSpec("solo", STABLE, max_slots=2, capacity=32,
                          prompt_len=5, max_new=4)
        gw = MultiTenantGateway([spec], _gcfg(), seed=0)
        gw.submit("solo", np.arange(5))
        gw.run_until_drained()
        m = gw.metrics()
        assert set(m) == {"steps", "kv_bytes_in_use", "deferred_admissions",
                          "reschedules", "tenants"}
        assert tuple(m["tenants"]["solo"]) == METRIC_KEYS

    def test_admission_gate_defers_and_preserves_fifo(self):
        model = build(STABLE)
        params = model.init(jax.random.PRNGKey(0))
        gate = {"open": False}
        eng = ServingEngine(model, params, max_slots=2, capacity=32,
                            admission_gate=lambda req: gate["open"])
        r1 = eng.submit(np.arange(5), max_new=3)
        r2 = eng.submit(np.arange(5), max_new=3)
        assert eng.step() == 0 and eng.active == 0     # everything deferred
        gate["open"] = True
        eng.step()
        assert eng.slots[0] is r1 and eng.slots[1] is r2   # FIFO kept
